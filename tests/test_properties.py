"""Property-based invariants for the optimization core (+ seeded twins).

Three subsystems get algebraic contracts here rather than example tests:

* :func:`repro.core.pareto.pareto_mask` / ``pareto_mask_batched`` -- no
  dominated point survives, every eliminated point has a witness, and the
  surviving *value set* is invariant under permutation and duplication
  (the tie contract pareto.py documents);
* the eq.-18 reduction (:meth:`CodesignResult.best`) -- the best
  achievable GFLOP/s is monotone in the area budget, and uniformly
  scaling every cell time scales the objective by exactly the inverse
  (the argmax is invariant);
* :func:`repro.core.portfolio.optimize_portfolio_arrays` -- K=1 under the
  throughput objective degenerates bit-for-bit to ``best()``, assignment
  rows are one-hot, and a fleet never does worse than the best single
  design it could have been.

Every ``@given`` property has a seeded deterministic twin exercising the
same checker, so a machine without hypothesis (the shim skips the
properties) still runs the invariants over a fixed corpus.
"""

import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips, not errors

from repro.core.codesign import HardwareSpace, codesign
from repro.core.pareto import pareto_front, pareto_mask, pareto_mask_batched
from repro.core.portfolio import optimize_portfolio_arrays, portfolio_candidates
from repro.core.solver import TileLattice
from repro.core.workload import Workload, WorkloadCell, paper_workload
from repro.core.area import MAXWELL

# ---------------------------------------------------------------------------
# checkers (shared by the hypothesis properties and the seeded twins)
# ---------------------------------------------------------------------------


def check_pareto_contract(cost, perf):
    """The full pareto_mask contract on one (cost, perf) instance."""
    cost = np.asarray(cost, np.float64)
    perf = np.asarray(perf, np.float64)
    mask = pareto_mask(cost, perf)
    finite = np.isfinite(cost) & np.isfinite(perf)
    assert not mask[~finite].any(), "non-finite point survived"
    for i in np.nonzero(mask)[0]:
        dominated = (cost <= cost[i]) & (perf > perf[i]) & finite
        assert not dominated.any(), f"survivor {i} is dominated"
        dup = (cost == cost[i]) & (perf == perf[i]) & finite
        assert i == int(np.nonzero(dup)[0][0]), (
            f"duplicate survivor {i} is not the lowest index"
        )
    for i in np.nonzero(finite & ~mask)[0]:
        # every eliminated finite point has a witness: a strictly better
        # point, or an equal-value duplicate at a lower index
        better = finite & (
            ((cost < cost[i]) & (perf >= perf[i]))
            | ((cost <= cost[i]) & (perf > perf[i]))
        )
        dup_lower = (
            finite & (cost == cost[i]) & (perf == perf[i])
            & (np.arange(cost.size) < i) & mask
        )
        assert better.any() or dup_lower.any(), f"point {i} eliminated without witness"
    return mask


def check_pareto_invariance(cost, perf, rng):
    """Surviving (cost, perf) value set is permutation/duplication-invariant."""
    cost = np.asarray(cost, np.float64)
    perf = np.asarray(perf, np.float64)
    mask = pareto_mask(cost, perf)
    values = sorted(zip(cost[mask].tolist(), perf[mask].tolist()))

    p = rng.permutation(cost.size)
    mask_p = pareto_mask(cost[p], perf[p])
    assert sorted(zip(cost[p][mask_p].tolist(), perf[p][mask_p].tolist())) == values

    cost2, perf2 = np.concatenate([cost, cost]), np.concatenate([perf, perf])
    mask2 = pareto_mask(cost2, perf2)
    assert sorted(zip(cost2[mask2].tolist(), perf2[mask2].tolist())) == values
    assert not mask2[cost.size:].any(), "a duplicated copy survived over the original"


def best_arrays(area, cell_time, cell_flops, freqs, budget):
    """The eq.-18 reduction on raw arrays (CodesignResult.best's algebra)."""
    wt = freqs @ cell_time
    g = (freqs @ cell_flops) / wt / 1.0e9
    g = np.where(np.asarray(area) <= budget, g, -np.inf)
    i = int(np.argmax(g))
    return i, float(g[i])


def check_portfolio_contract(area, cell_time, cell_flops, freqs, k, budget):
    """K=1 degeneracy + one-hot rows + fleet >= best single design."""
    best_i, best_g = best_arrays(area, cell_time, cell_flops, freqs, budget)
    r1 = optimize_portfolio_arrays(
        area, cell_time, cell_flops, freqs, 1, budget, objective="throughput"
    )
    assert r1.members == (best_i,), "K=1 named a different design than best()"
    assert r1.fleet_gflops == best_g, "K=1 objective is not bit-equal to best()"

    rk = optimize_portfolio_arrays(
        area, cell_time, cell_flops, freqs, k, budget, objective="throughput"
    )
    a = rk.assignment
    assert a.shape == (len(cell_time), len(rk.members))
    np.testing.assert_array_equal(a.sum(axis=1), np.ones(len(cell_time)))
    assert ((a == 0.0) | (a == 1.0)).all(), "assignment is not one-hot"
    assert rk.fleet_gflops >= best_g * (1 - 1e-12), (
        f"fleet {rk.fleet_gflops} worse than single design {best_g}"
    )
    assert rk.total_area <= budget + 1e-9 * abs(budget)
    return rk


def random_portfolio_instance(rng, n_cells=None, n_hw=None):
    C = n_cells or int(rng.integers(1, 5))
    H = n_hw or int(rng.integers(2, 9))
    area = rng.uniform(1.0, 100.0, H)
    cell_time = rng.uniform(0.1, 10.0, (C, H))
    cell_flops = rng.uniform(1e6, 1e9, C)
    freqs = rng.uniform(0.1, 3.0, C)
    return area, cell_time, cell_flops, freqs


# ---------------------------------------------------------------------------
# a real (tiny) codesign result for the eq.-18 / portfolio-degeneracy tests
# ---------------------------------------------------------------------------

TINY_LATTICE = TileLattice(t_s1=(2, 8), t_s2=(32, 128), t_t=(4, 16), k=(1, 4))

_CACHE = {}


def tiny_result():
    """A 12-point hardware space x 3-cell workload, numpy engine (cheap
    enough to build once per test session, real enough that the reduction
    under test is the production one)."""
    if "res" not in _CACHE:
        n_sm = np.repeat([2.0, 8.0, 16.0, 32.0], 3)
        n_v = np.tile([64.0, 256.0, 1024.0], 4)
        m_sm = np.tile([48.0, 96.0, 192.0, 384.0], 3)
        area = MAXWELL.area(n_sm, n_v, m_sm)
        hw = HardwareSpace(n_sm, n_v, m_sm, area)
        wl = paper_workload(["jacobi2d", "heat2d"])
        wl = Workload("tiny", tuple(
            WorkloadCell(c.stencil, c.size, 1.0 / 3) for c in wl.cells[:3]
        ))
        _CACHE["res"] = codesign(wl, hw=hw, lattice_2d=TINY_LATTICE, engine="numpy")
    return _CACHE["res"]


# ---------------------------------------------------------------------------
# pareto: hypothesis properties + seeded twins + duplicate regression
# ---------------------------------------------------------------------------

finite_f = st.floats(
    min_value=-1e6, max_value=1e6, allow_nan=False, allow_infinity=False
)


@settings(max_examples=200)
@given(
    st.lists(st.tuples(finite_f, finite_f), min_size=1, max_size=40),
    st.integers(min_value=0, max_value=2**32 - 1),
)
def test_pareto_mask_properties(points, seed):
    cost = np.array([p[0] for p in points])
    perf = np.array([p[1] for p in points])
    check_pareto_contract(cost, perf)
    check_pareto_invariance(cost, perf, np.random.default_rng(seed))


@settings(max_examples=100)
@given(
    st.integers(min_value=1, max_value=12),
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=0, max_value=2**32 - 1),
)
def test_pareto_mask_batched_matches_rows(n, b, seed):
    rng = np.random.default_rng(seed)
    # coarse quantization manufactures plenty of cost/perf ties
    cost = np.round(rng.uniform(0, 5, n))
    perf = np.round(rng.uniform(0, 5, (b, n)))
    batched = pareto_mask_batched(cost, perf)
    for row in range(b):
        np.testing.assert_array_equal(batched[row], pareto_mask(cost, perf[row]))
        check_pareto_contract(cost, perf[row])


def test_pareto_properties_seeded_twin():
    """The same contract over a fixed corpus -- runs without hypothesis."""
    rng = np.random.default_rng(7)
    for _ in range(40):
        n = int(rng.integers(1, 30))
        # quantized draws force duplicate (cost, perf) pairs regularly
        cost = np.round(rng.uniform(0, 8, n) * 2) / 2
        perf = np.round(rng.uniform(0, 8, n) * 2) / 2
        check_pareto_contract(cost, perf)
        check_pareto_invariance(cost, perf, rng)
        batched = pareto_mask_batched(cost, np.stack([perf, perf[::-1]]))
        np.testing.assert_array_equal(batched[0], pareto_mask(cost, perf))
        np.testing.assert_array_equal(batched[1], pareto_mask(cost, perf[::-1]))


def test_pareto_duplicate_lowest_index_regression():
    """Exact duplicates keep ONLY the lowest original index -- the tie
    contract pareto.py documents and portfolio enumeration relies on."""
    cost = np.array([2.0, 1.0, 2.0, 1.0, 1.0])
    perf = np.array([5.0, 3.0, 5.0, 3.0, 3.0])
    mask = pareto_mask(cost, perf)
    #          dup of 0 at 2; dups of 1 at 3, 4; 0 dominates nothing (cost
    #          higher but perf higher too -> both fronts survive once)
    np.testing.assert_array_equal(mask, [True, True, False, False, False])

    # permuting moves the survivors with their (new) lowest index
    p = np.array([4, 2, 0, 3, 1])
    mask_p = pareto_mask(cost[p], perf[p])
    np.testing.assert_array_equal(mask_p, [True, True, False, False, False])


def test_pareto_front_deterministic_with_duplicates():
    cost = np.array([3.0, 1.0, 3.0, 1.0, 2.0])
    perf = np.array([9.0, 4.0, 9.0, 4.0, 6.0])
    c, p, idx = pareto_front(cost, perf)
    np.testing.assert_array_equal(idx, [1, 4, 0])  # lowest index per value
    assert (np.diff(c) > 0).all() and (np.diff(p) > 0).all()


# ---------------------------------------------------------------------------
# eq.-18 reduction: budget monotonicity + time scaling
# ---------------------------------------------------------------------------

budget_f = st.floats(min_value=0.0, max_value=700.0, allow_nan=False)


@settings(max_examples=50)
@given(budget_f, budget_f)
def test_best_budget_monotone(b1, b2):
    res = tiny_result()
    lo, hi = sorted((b1, b2))
    _, g_lo = res.best(max_area=lo)
    _, g_hi = res.best(max_area=hi)
    assert g_lo <= g_hi, "a bigger area budget made the best design worse"


@settings(max_examples=50)
@given(st.floats(min_value=1e-3, max_value=1e3, allow_nan=False))
def test_best_time_scaling(scale):
    res = tiny_result()
    scaled = dataclasses.replace(res, cell_time=res.cell_time * scale)
    i0, g0 = res.best(max_area=500.0)
    i1, g1 = scaled.best(max_area=500.0)
    assert i1 == i0, "uniform time scaling moved the argmax"
    assert g1 == pytest.approx(g0 / scale, rel=1e-9)


def test_eq18_properties_seeded_twin():
    res = tiny_result()
    budgets = [0.0, 50.0, 120.0, 250.0, 400.0, 650.0, np.inf]
    values = [res.best(max_area=b)[1] for b in budgets]
    assert values == sorted(values)
    for scale in (0.125, 0.5, 3.0, 64.0):
        scaled = dataclasses.replace(res, cell_time=res.cell_time * scale)
        i0, g0 = res.best(max_area=500.0)
        i1, g1 = scaled.best(max_area=500.0)
        assert i1 == i0 and g1 == pytest.approx(g0 / scale, rel=1e-9)


# ---------------------------------------------------------------------------
# portfolio: K=1 degeneracy, one-hot assignment, fleet >= single design
# ---------------------------------------------------------------------------


@settings(max_examples=50)
@given(
    st.integers(min_value=0, max_value=2**32 - 1),
    st.integers(min_value=1, max_value=3),
    st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
)
def test_portfolio_properties(seed, k, budget_frac):
    rng = np.random.default_rng(seed)
    area, cell_time, cell_flops, freqs = random_portfolio_instance(rng)
    # budget spans [cheapest single design, whole catalog] -> always feasible
    budget = float(area.min() + budget_frac * (area.sum() - area.min()))
    check_portfolio_contract(area, cell_time, cell_flops, freqs, k, budget)


def test_portfolio_properties_seeded_twin():
    rng = np.random.default_rng(11)
    for _ in range(25):
        area, cell_time, cell_flops, freqs = random_portfolio_instance(rng)
        budget = float(rng.uniform(area.min(), area.sum()))
        k = int(rng.integers(1, 4))
        check_portfolio_contract(area, cell_time, cell_flops, freqs, k, budget)


def test_portfolio_k1_degenerates_on_real_sweep():
    """K=1 + throughput objective == codesign().best(), bit for bit, on a
    real (tiny) sweep -- the acceptance identity, not just synthetics."""
    res = tiny_result()
    area = res.hw.area
    for budget in (float(area.min()), 120.0, 300.0, float(area.max())):
        best_i, best_g = res.best(max_area=budget)
        r = optimize_portfolio_arrays(
            area, res.cell_time, res.cell_flops(), res.cell_freqs(),
            1, budget, objective="throughput",
        )
        assert r.members == (best_i,)
        assert r.fleet_gflops == best_g


def test_portfolio_candidates_never_lose_optimal_value():
    """Restricting k>=2 subsets to full-vector-dominance candidates is
    value-lossless: brute force over ALL subsets finds the same optimum."""
    import itertools

    rng = np.random.default_rng(3)
    for _ in range(10):
        area, cell_time, cell_flops, freqs = random_portfolio_instance(
            rng, n_hw=6
        )
        budget = float(rng.uniform(area.min(), area.sum()))
        for k in (2, 3):
            r = optimize_portfolio_arrays(
                area, cell_time, cell_flops, freqs, k, budget,
                objective="throughput",
            )
            best = -np.inf
            for size in range(1, k + 1):
                for sub in itertools.combinations(range(len(area)), size):
                    if area[list(sub)].sum() > budget:
                        continue
                    t = cell_time[:, list(sub)].min(axis=1)
                    wt = freqs @ t
                    best = max(best, float((freqs @ cell_flops) / wt / 1e9))
            assert r.fleet_gflops == pytest.approx(best, rel=1e-12)


def test_portfolio_candidates_duplicate_lowest_index():
    area = np.array([1.0, 1.0, 2.0])
    cell_time = np.array([[3.0, 3.0, 3.0], [2.0, 2.0, 2.0]])
    mask = portfolio_candidates(area, cell_time)
    # 1 duplicates 0 (same area, same column) -> only 0 survives; 2 is
    # dominated outright (more area, no faster anywhere)
    assert np.nonzero(mask)[0].tolist() == [0]
