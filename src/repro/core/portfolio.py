"""Portfolio codesign: K design points + a traffic assignment (fleet eq. 18).

Eq. 18 picks ONE hardware point for a workload mix; a fleet runs a *mix*
of designs and routes each workload cell to the design that serves it
best (ROADMAP "Portfolio codesign + heterogeneity-aware routing"; the
charm-style heterogeneous codesign direction). Given the swept
``(C, H)`` cell-time matrix, a traffic distribution over cells, and a
fleet budget (total silicon area, or total chips for LM cells), choose
**up to K design points** plus an assignment of every cell's traffic to
a chosen design, maximizing either

* ``objective="throughput"`` -- fleet GFLOP/s subject to total area <=
  budget (``k=1`` is then *exactly* ``CodesignResult.best(max_area=budget)``,
  same arithmetic, same argmax tie-break); or
* ``objective="density"``    -- fleet GFLOP/s per unit total area under
  the same budget (the ROADMAP objective; the default).

Structure of the optimum, used by both engines:

* Given a chosen set S, the fleet weighted time is linear in the
  assignment matrix, so the inner assignment problem is solved at a
  vertex: each cell one-hot routes ALL of its traffic to its fastest
  design in S (cells are separable given S -- the "greedy-optimal"
  inner step). The outer problem is therefore a subset search.
* Singletons are enumerated over the FULL hardware space in ascending
  index order (bit-reproducing ``best()``'s first-max argmax); subsets of
  size >= 2 only over the dominance-surviving candidate set
  (:func:`portfolio_candidates`), which is lossless for the optimal
  value: replacing a dominated member with its dominator never worsens
  time on any cell and never grows the area sum.
* "Up to K": sizes 1..K are all enumerated with a strict ``>`` running
  max, so the reported fleet objective is monotone in K and always >=
  the best single design, and ties resolve to the
  first-in-enumeration-order (smallest, then lexicographically lowest)
  subset -- deterministic because :mod:`repro.core.pareto`'s masks and
  the dominance filter here break every tie toward the lowest index.

The candidate filter must be FULL-VECTOR dominance (area plus the whole
per-cell time column), not a union of per-cell 2-D Pareto fronts: a
"generalist" design dominated on every individual cell by some
specialist can still be the unique optimum when the budget fits only
one chip (e.g. cells {1,2}, A=(area 1, t=(1,100)), B=(area 1,
t=(100,1)), M=(area 1.5, t=(2,2)), budget 1.5, even mix: {M} wins).

Two equivalence-tested engines: an exact float64 NumPy oracle
(explicit loop over subsets -- the trust anchor) and a jitted JAX
engine scoring every subset in one fused gather/min/matvec reduction
(float32, tie-aware equivalent; the winning subset's reported numbers
are always recomputed through the float64 path, so engines can only
differ in which of two near-tied subsets they name).
"""

from __future__ import annotations

import functools
import itertools
import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = [
    "OBJECTIVES",
    "PortfolioResult",
    "optimize_portfolio",
    "optimize_portfolio_arrays",
    "portfolio_candidates",
]

OBJECTIVES = ("density", "throughput")

# subsets are scored in one vectorized pass; past this many the fused
# (C, M, K) gather stops fitting comfortably in memory -- downsample the
# hardware space (the CLI's --downsample) instead of brute-forcing it
_MAX_SUBSETS_DEFAULT = 200_000


def portfolio_candidates(
    area: np.ndarray, cell_time: np.ndarray, chunk: int = 512
) -> np.ndarray:
    """Boolean mask of designs that can appear in some optimal portfolio.

    A design ``h`` is dominated iff some ``h'`` has ``area[h'] <= area[h]``
    and ``cell_time[:, h'] <= cell_time[:, h]`` componentwise, strictly on
    at least one axis; exact duplicates keep the lowest index (the same
    tie contract as :mod:`repro.core.pareto`). O(H^2 * C) in chunked
    vectorized passes -- meant for the downsampled spaces portfolios are
    built from, not the full million-point lattice.
    """
    area = np.asarray(area, np.float64).ravel()
    t = np.asarray(cell_time, np.float64)
    if t.ndim != 2 or t.shape[1] != area.shape[0]:
        raise ValueError("cell_time must be (C, H) matching area (H,)")
    t = np.where(np.isnan(t), np.inf, t)  # infeasible cells compare as inf
    n_cells, n_hw = t.shape
    dominated = np.zeros(n_hw, dtype=bool)
    idx = np.arange(n_hw)
    for s in range(0, n_hw, chunk):
        d = slice(s, min(s + chunk, n_hw))
        a_d = area[d][:, None]
        all_le = a_d <= area[None, :]
        any_lt = a_d < area[None, :]
        for c in range(n_cells):
            t_d = t[c, d][:, None]
            all_le &= t_d <= t[c][None, :]
            any_lt |= t_d < t[c][None, :]
        strict = all_le & any_lt
        duplicate = all_le & ~any_lt  # equal on every axis (includes self)
        dom = strict | (duplicate & (idx[d][:, None] < idx[None, :]))
        dominated |= dom.any(axis=0)
    return ~dominated


@dataclass(frozen=True)
class PortfolioResult:
    """A chosen fleet: up to K designs plus the per-cell traffic routing."""

    k: int  # requested K (len(members) may be smaller: "up to K")
    objective: str
    budget: float
    members: Tuple[int, ...]  # chosen hw indices, ascending
    assignment: np.ndarray  # (C, len(members)) one-hot rows, rows sum to 1
    preference: np.ndarray  # (C, len(members)) member slots, fastest first
    freqs: np.ndarray  # (C,) traffic distribution actually used
    weighted_time: float  # fleet eq.-17 objective at the optimum
    fleet_gflops: float
    total_area: float
    fleet_density: float  # fleet_gflops / total_area
    candidates: Tuple[int, ...]  # dominance survivors (audit trail)
    engine: str  # "numpy" | "jax"

    def assigned_member(self, cell_index: int) -> int:
        """The hw index serving all of ``cell_index``'s traffic."""
        return self.members[int(np.argmax(self.assignment[cell_index]))]

    def payload(self) -> Dict[str, object]:
        """Canonical-JSON-able body for a ``kind: "portfolio"`` manifest.

        Pure python scalars/lists (json round-trips float64 losslessly),
        key order irrelevant -- the store canonicalizes with sorted keys,
        so identical optimizations produce identical bytes and content
        keys regardless of engine or writer.
        """
        return {
            "k": int(self.k),
            "objective": self.objective,
            "budget": float(self.budget),
            "members": [int(m) for m in self.members],
            "assignment": [[float(x) for x in row] for row in self.assignment],
            "preference": [[int(x) for x in row] for row in self.preference],
            "freqs": [float(x) for x in self.freqs],
            "weighted_time": float(self.weighted_time),
            "fleet_gflops": float(self.fleet_gflops),
            "total_area": float(self.total_area),
            "fleet_density": float(self.fleet_density),
            "candidates": [int(c) for c in self.candidates],
            "engine": self.engine,
        }


def _finalize_subset(
    members: Tuple[int, ...],
    area: np.ndarray,
    times: np.ndarray,
    freqs: np.ndarray,
    numer: float,
    *,
    k: int,
    objective: str,
    budget: float,
    candidates: Tuple[int, ...],
    engine: str,
) -> PortfolioResult:
    """Exact float64 report for a chosen subset (shared by both engines)."""
    sub = times[:, members]  # (C, K')
    slot = np.argmin(sub, axis=1)  # fastest member per cell; ties -> low slot
    assignment = np.zeros(sub.shape, np.float64)
    assignment[np.arange(sub.shape[0]), slot] = 1.0
    preference = np.argsort(sub, axis=1, kind="stable").astype(np.int64)
    if len(members) == 1:
        # same full-matrix matvec CodesignResult.weighted_time() runs, so
        # the K=1 degeneracy is bit-exact (a per-column dot can round the
        # last ulp differently than BLAS's matvec)
        wt = float((freqs @ times)[members[0]])
    else:
        wt = float(freqs @ sub.min(axis=1))
    total_area = float(np.sum(area[list(members)]))
    gflops = numer / wt / 1.0e9
    return PortfolioResult(
        k=k,
        objective=objective,
        budget=float(budget),
        members=tuple(int(m) for m in members),
        assignment=assignment,
        preference=preference,
        freqs=np.asarray(freqs, np.float64).copy(),
        weighted_time=wt,
        fleet_gflops=float(gflops),
        total_area=total_area,
        fleet_density=float(gflops / total_area),
        candidates=candidates,
        engine=engine,
    )


def _subset_universe(
    n_hw: int, cand: np.ndarray, k: int, max_subsets: int
) -> list:
    """Enumeration order shared by both engines: all singletons (ascending
    hw index), then size-2..K combinations of the candidate set."""
    total = n_hw
    for size in range(2, k + 1):
        total += math.comb(cand.shape[0], size)
    if total > max_subsets:
        raise ValueError(
            f"portfolio enumeration would score {total} subsets "
            f"(> max_subsets={max_subsets}); downsample the hardware "
            f"space or lower k"
        )
    subsets = [(int(h),) for h in range(n_hw)]
    for size in range(2, k + 1):
        subsets.extend(
            tuple(int(cand[j]) for j in combo)
            for combo in itertools.combinations(range(cand.shape[0]), size)
        )
    return subsets


def _score_numpy(
    subsets: list,
    area: np.ndarray,
    times: np.ndarray,
    freqs: np.ndarray,
    numer: float,
    budget: float,
    objective: str,
) -> int:
    """Exact oracle: explicit float64 loop, strict ``>`` keeps the first
    (smallest, lexicographically lowest) of tied subsets. Singletons use
    the same full-matrix ``freqs @ times`` matvec as ``gflops()`` so a
    k=1 throughput answer is bit-identical to ``best()``."""
    wt_single = freqs @ times  # (H,) -- best()'s own reduction
    best_obj = -np.inf
    best_i = -1
    for i, sub in enumerate(subsets):
        if len(sub) == 1:
            wt = wt_single[sub[0]]
            total_area = area[sub[0]]
        else:
            wt = float(freqs @ np.min(times[:, sub], axis=1))
            total_area = float(np.sum(area[list(sub)]))
        gflops = numer / wt / 1.0e9
        obj = gflops / total_area if objective == "density" else gflops
        if total_area <= budget and np.isfinite(obj) and obj > best_obj:
            best_obj = obj
            best_i = i
    return best_i


@functools.lru_cache(maxsize=None)
def _jax_scorer(objective: str):
    """Jitted subset scorer, compiled once per objective (numer/budget are
    traced scalars, so sweeps over budgets reuse the same executable)."""
    import jax
    import jax.numpy as jnp

    def score(times_d, area_d, freqs_d, idx_d, valid_d, numer, budget):
        t = times_d[:, idx_d]  # (C, M, K)
        t = jnp.where(valid_d[None, :, :], t, jnp.inf)
        wt = freqs_d @ t.min(axis=2)  # (M,)
        total_area = jnp.where(valid_d, area_d[idx_d], 0.0).sum(axis=1)
        gflops = numer / wt / 1.0e9
        if objective == "density":
            obj = gflops / total_area
        else:
            obj = gflops
        ok = (total_area <= budget) & jnp.isfinite(obj)
        return jnp.argmax(jnp.where(ok, obj, -jnp.inf)), ok.any()

    return jax.jit(score)


def _score_jax(
    subsets: list,
    area: np.ndarray,
    times: np.ndarray,
    freqs: np.ndarray,
    numer: float,
    budget: float,
    objective: str,
    k: int,
) -> int:
    """Fused JAX scorer: pad subsets to width K (mask-aware), gather the
    (C, M, K) time block, min over members, one matvec for every fleet's
    weighted time. float32 on device; the caller re-reports in float64."""
    import jax.numpy as jnp

    m = len(subsets)
    idx = np.zeros((m, k), np.int32)
    valid = np.zeros((m, k), bool)
    for i, sub in enumerate(subsets):
        idx[i, : len(sub)] = sub
        valid[i, : len(sub)] = True

    best, any_ok = _jax_scorer(objective)(
        jnp.asarray(times, jnp.float32),
        jnp.asarray(area, jnp.float32),
        jnp.asarray(freqs, jnp.float32),
        jnp.asarray(idx),
        jnp.asarray(valid),
        float(numer),
        float(budget),
    )
    return int(best) if bool(any_ok) else -1


def optimize_portfolio_arrays(
    area: np.ndarray,
    cell_time: np.ndarray,
    cell_flops: np.ndarray,
    freqs: np.ndarray,
    k: int,
    budget: float,
    *,
    objective: str = "density",
    engine: str = "numpy",
    max_subsets: int = _MAX_SUBSETS_DEFAULT,
) -> PortfolioResult:
    """Array-level portfolio optimization (the service/artifact path)."""
    if objective not in OBJECTIVES:
        raise ValueError(f"objective must be one of {OBJECTIVES}, got {objective!r}")
    if engine not in ("numpy", "jax"):
        raise ValueError(f"engine must be 'numpy' or 'jax', got {engine!r}")
    k = int(k)
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    area = np.asarray(area, np.float64).ravel()
    times = np.asarray(cell_time, np.float64)
    freqs = np.asarray(freqs, np.float64).ravel()
    flops = np.asarray(cell_flops, np.float64).ravel()
    if times.shape != (freqs.shape[0], area.shape[0]):
        raise ValueError("cell_time must be (C, H) matching freqs/area")
    if (freqs < 0).any() or not np.isfinite(freqs).all():
        raise ValueError("freqs must be finite and non-negative")
    numer = float(freqs @ flops)

    cand = np.nonzero(portfolio_candidates(area, times))[0]
    subsets = _subset_universe(area.shape[0], cand, k, max_subsets)
    if engine == "jax":
        best_i = _score_jax(subsets, area, times, freqs, numer, budget, objective, k)
    else:
        best_i = _score_numpy(subsets, area, times, freqs, numer, budget, objective)
    if best_i < 0:
        raise ValueError(
            f"no feasible portfolio: no subset of <= {k} designs fits "
            f"budget {budget} with a finite fleet objective"
        )
    return _finalize_subset(
        subsets[best_i],
        area,
        times,
        freqs,
        numer,
        k=k,
        objective=objective,
        budget=budget,
        candidates=tuple(int(c) for c in cand),
        engine=engine,
    )


def optimize_portfolio(
    result,
    k: int,
    budget: float,
    freqs: Optional[np.ndarray] = None,
    *,
    objective: str = "density",
    engine: str = "numpy",
    max_subsets: int = _MAX_SUBSETS_DEFAULT,
) -> PortfolioResult:
    """Portfolio over a :class:`~repro.core.codesign.CodesignResult` (or
    any object with ``hw.area`` / ``cell_time`` / ``cell_freqs()`` /
    ``cell_flops()`` -- LM results and stored artifacts qualify via
    :func:`optimize_portfolio_arrays`). ``freqs`` defaults to the
    workload's own mix, unnormalized, exactly as ``best()`` consumes it.
    """
    return optimize_portfolio_arrays(
        result.hw.area,
        result.cell_time,
        result.cell_flops(),
        result.cell_freqs() if freqs is None else freqs,
        k,
        budget,
        objective=objective,
        engine=engine,
        max_subsets=max_subsets,
    )
