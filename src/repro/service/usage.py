"""Persistent per-artifact usage accounting + kind-aware retention.

The gateway's in-memory hit counters die with the process, which makes
them useless for the question retention actually asks: *which artifacts
earn their disk over weeks?* This module persists the accounting:

* :class:`UsageLedger` -- per-store-root hit/byte/last-access/client
  accounting, buffered in memory and periodically flushed to one atomic
  JSON file **beside** the root (``.usage-ledger.json``; dot-prefixed so
  :meth:`ArtifactStore.keys` never mistakes it for an artifact). Flushes
  MERGE with the on-disk state under a bounded ``flock`` (the same
  discipline as build locks), so N gateway replicas over one shared root
  each fold their deltas in without losing each other's -- and a restart
  resumes exactly where the last flush left off.
* :func:`retention_plan` -- a deterministic, kind-aware GC plan over a
  store's entries + its ledger: telemetry snapshots age out first (cap,
  oldest-first), sweeps referenced by a live portfolio member are never
  evicted, and an optional total-artifact cap evicts the coldest
  unprotected artifacts (fewest hits, oldest access, key order). The
  plan is pure data -- ``cli gc --dry-run`` prints it, ``--apply``
  executes it via :meth:`ArtifactStore.delete`.

Nothing here is ever on the answer path: :meth:`UsageLedger.record` is a
dict update under one lock, and a flush that cannot win the file lock
within its bound simply keeps its deltas buffered for the next try.
"""

from __future__ import annotations

import json
import os
import tempfile
import time
from threading import Lock
from typing import Any, Dict, List, Optional, Sequence

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX: lock-free merge
    fcntl = None

__all__ = [
    "LEDGER_FILENAME",
    "LEDGER_VERSION",
    "UsageLedger",
    "retention_plan",
]

LEDGER_FILENAME = ".usage-ledger.json"
LEDGER_VERSION = 1

#: distinct client buckets tracked per artifact before folding the long
#: tail into ``"other"`` -- the ledger must stay small no matter how
#: many X-Repro-Client values the internet invents.
MAX_CLIENT_BUCKETS = 16


def _merge_record(into: Dict[str, Any], delta: Dict[str, Any]) -> None:
    into["hits"] = int(into.get("hits", 0)) + int(delta.get("hits", 0))
    into["bytes"] = int(into.get("bytes", 0)) + int(delta.get("bytes", 0))
    la = delta.get("last_access")
    if la is not None and (into.get("last_access") is None
                           or la > into["last_access"]):
        into["last_access"] = la
    clients = into.setdefault("clients", {})
    for bucket, n in delta.get("clients", {}).items():
        clients[bucket] = int(clients.get(bucket, 0)) + int(n)
    if len(clients) > MAX_CLIENT_BUCKETS:
        # deterministic fold: keep the highest-traffic buckets, sum the
        # tail into "other" (ties break by name so replicas agree)
        keep = sorted(clients.items(), key=lambda kv: (-kv[1], kv[0]))
        head = dict(keep[: MAX_CLIENT_BUCKETS - 1])
        tail = sum(n for _, n in keep[MAX_CLIENT_BUCKETS - 1:])
        head["other"] = head.pop("other", 0) + tail
        clients.clear()
        clients.update(head)


class UsageLedger:
    """Crash-safe usage accounting for one artifact-store root."""

    def __init__(self, root: str, *, flush_interval_s: float = 60.0,
                 clock=time.time, lock_timeout_s: float = 2.0):
        self.root = os.path.abspath(root)
        self.path = os.path.join(self.root, LEDGER_FILENAME)
        self._lock_path = os.path.join(self.root, LEDGER_FILENAME + ".lock")
        self._flush_interval = float(flush_interval_s)
        self._lock_timeout = float(lock_timeout_s)
        self._clock = clock
        self._mu = Lock()
        self._pending: Dict[str, Dict[str, Any]] = {}
        self._last_flush = float(clock())
        self._persisted = self._read_file()

    # ---- disk ---------------------------------------------------------------
    def _read_file(self) -> Dict[str, Dict[str, Any]]:
        try:
            with open(self.path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            return {}
        if not isinstance(doc, dict) or doc.get("v") != LEDGER_VERSION:
            return {}
        arts = doc.get("artifacts")
        return dict(arts) if isinstance(arts, dict) else {}

    def _locked(self):
        """Bounded-wait exclusive flock over the ledger file, or None when
        the lock cannot be won in time (callers then skip the flush and
        keep deltas buffered -- serving never blocks on accounting)."""
        if fcntl is None:
            return -1  # lock-free platforms: merge unatomically but honestly
        fd = os.open(self._lock_path, os.O_CREAT | os.O_RDWR, 0o644)
        t0 = time.perf_counter()
        while True:
            try:
                fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
                return fd
            except (BlockingIOError, InterruptedError):
                if time.perf_counter() - t0 >= self._lock_timeout:
                    os.close(fd)
                    return None
                time.sleep(0.005)

    def _unlock(self, fd: int) -> None:
        if fd >= 0 and fcntl is not None:
            fcntl.flock(fd, fcntl.LOCK_UN)
            os.close(fd)

    # ---- write path ---------------------------------------------------------
    def record(self, key: str, n: int = 1, nbytes: int = 0,
               client: Optional[str] = None) -> None:
        """Buffer one access. O(1), one lock, no I/O."""
        now = float(self._clock())
        with self._mu:
            rec = self._pending.setdefault(
                key, {"hits": 0, "bytes": 0, "last_access": None, "clients": {}}
            )
            rec["hits"] += int(n)
            rec["bytes"] += int(nbytes)
            rec["last_access"] = now
            if client:
                b = str(client)[:64]
                rec["clients"][b] = rec["clients"].get(b, 0) + int(n)

    def maybe_flush(self) -> bool:
        """Flush iff the interval elapsed and there is anything to write.
        Cheap enough for a request path (one clock read when idle)."""
        with self._mu:
            due = (self._pending
                   and float(self._clock()) - self._last_flush
                   >= self._flush_interval)
        return self.flush() if due else False

    def flush(self) -> bool:
        """Merge buffered deltas into the on-disk ledger atomically.
        Returns True when the file was updated; False when there was
        nothing to write or the file lock could not be won (deltas stay
        buffered -- nothing is lost either way)."""
        with self._mu:
            if not self._pending:
                self._last_flush = float(self._clock())
                return False
            pending, self._pending = self._pending, {}
        fd = self._locked()
        if fd is None:
            with self._mu:  # lock contention: re-buffer for the next try
                for key, delta in pending.items():
                    rec = self._pending.setdefault(
                        key, {"hits": 0, "bytes": 0, "last_access": None,
                              "clients": {}}
                    )
                    _merge_record(rec, delta)
            return False
        try:
            disk = self._read_file()
            for key, delta in pending.items():
                _merge_record(disk.setdefault(key, {}), delta)
            doc = {
                "v": LEDGER_VERSION,
                "updated_at": float(self._clock()),
                "artifacts": disk,
            }
            tmpfd, tmp = tempfile.mkstemp(prefix=".usage-", dir=self.root)
            try:
                with os.fdopen(tmpfd, "w") as f:
                    json.dump(doc, f, sort_keys=True, separators=(",", ":"))
                os.replace(tmp, self.path)
            except BaseException:
                if os.path.exists(tmp):
                    os.unlink(tmp)
                raise
            with self._mu:
                self._persisted = disk
                self._last_flush = float(self._clock())
            return True
        finally:
            self._unlock(fd)

    # ---- read path ----------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """Merged persisted + buffered view, per artifact key. The shape
        each record takes: ``{hits, bytes, last_access, clients}``."""
        with self._mu:
            merged: Dict[str, Dict[str, Any]] = {
                k: {"hits": int(v.get("hits", 0)),
                    "bytes": int(v.get("bytes", 0)),
                    "last_access": v.get("last_access"),
                    "clients": dict(v.get("clients", {}))}
                for k, v in self._persisted.items()
            }
            for key, delta in self._pending.items():
                _merge_record(merged.setdefault(key, {}), delta)
        for rec in merged.values():
            rec.setdefault("hits", 0)
            rec.setdefault("bytes", 0)
            rec.setdefault("last_access", None)
            rec.setdefault("clients", {})
        return merged

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        """One artifact's merged record, or None when never accessed."""
        return self.snapshot().get(key)


def retention_plan(
    entries: Sequence[Dict[str, Any]],
    usage: Dict[str, Dict[str, Any]],
    *,
    telemetry_cap: int = 32,
    max_artifacts: Optional[int] = None,
) -> Dict[str, Any]:
    """A deterministic, kind-aware eviction plan for one store root.

    ``entries`` are :meth:`ArtifactStore.entries` rows (must carry
    ``key`` and ``kind``; portfolio rows carry the member ``sweep_key``
    either in the row or in the artifact payload -- pass it through as
    ``sweep_key``). ``usage`` is a :meth:`UsageLedger.snapshot`.

    Rules, in order:

    1. **Protected, never evicted**: portfolio manifests themselves, and
       any sweep referenced by a portfolio's ``sweep_key`` (evicting the
       matrix behind a live routing policy would turn ``/v1/route`` into
       503s).
    2. **Telemetry ages out first**: keep the newest ``telemetry_cap``
       snapshots (by ``collected_at``, ties by key), evict the rest.
    3. **Cold-artifact cap** (optional): when ``max_artifacts`` is set
       and the post-telemetry population still exceeds it, evict
       unprotected artifacts coldest-first -- fewest ledger hits, then
       oldest ``last_access`` (never-accessed sorts coldest), then key
       -- with measurements/calibrations/telemetry preferred over
       sweeps at equal coldness.

    The plan is plain data (canonical-JSON-stable): ``evict`` rows carry
    key/kind/reason, plus ``kept``/``protected`` key lists, so two
    replicas planning over the same root emit identical bytes.
    """
    if telemetry_cap < 0:
        raise ValueError(f"telemetry_cap must be >= 0, got {telemetry_cap}")
    rows = {str(e["key"]): e for e in entries}
    protected: Dict[str, str] = {}
    for key, e in rows.items():
        if e.get("kind") == "portfolio":
            protected[key] = "portfolio manifest"
            sk = e.get("sweep_key")
            if sk and sk in rows:
                protected[str(sk)] = f"sweep behind portfolio {key[:12]}"

    evict: List[Dict[str, Any]] = []
    evicted: set = set()

    # rule 2: telemetry cap, oldest collected_at first
    telemetry = [
        (e.get("collected_at") or 0.0, key)
        for key, e in rows.items()
        if e.get("kind") == "telemetry" and key not in protected
    ]
    telemetry.sort()
    if len(telemetry) > telemetry_cap:
        for at, key in telemetry[: len(telemetry) - telemetry_cap]:
            evict.append({
                "key": key,
                "kind": "telemetry",
                "reason": f"telemetry beyond cap {telemetry_cap} (oldest first)",
            })
            evicted.add(key)

    # rule 3: optional total cap, coldest unprotected first
    if max_artifacts is not None and max_artifacts >= 0:
        remaining = [k for k in rows if k not in evicted]
        if len(remaining) > max_artifacts:
            # sweeps evict last among equals: kind_rank orders the
            # expendable kinds ahead of the expensive-to-rebuild matrix
            kind_rank = {"telemetry": 0, "measurement": 1,
                         "calibration": 2, "sweep": 3, "portfolio": 4}
            def coldness(key: str):
                u = usage.get(key, {})
                return (
                    int(u.get("hits", 0)),
                    float(u.get("last_access") or 0.0),
                    kind_rank.get(rows[key].get("kind", "sweep"), 3),
                    key,
                )
            candidates = sorted(
                (k for k in remaining if k not in protected), key=coldness
            )
            need = len(remaining) - max_artifacts
            for key in candidates[:need]:
                u = usage.get(key, {})
                evict.append({
                    "key": key,
                    "kind": rows[key].get("kind", "sweep"),
                    "reason": (
                        f"over max_artifacts={max_artifacts}: "
                        f"{int(u.get('hits', 0))} hits"
                    ),
                })
                evicted.add(key)

    evict.sort(key=lambda e: e["key"])
    return {
        "evict": evict,
        "kept": sorted(k for k in rows if k not in evicted),
        "protected": {k: protected[k] for k in sorted(protected)},
        "telemetry_cap": telemetry_cap,
        "max_artifacts": max_artifacts,
    }
