"""Jacobi-2D: 5-point average (paper workload). out = 0.2*(c+n+s+e+w)."""

from __future__ import annotations

import jax

from .stencil_common import stencil2d_call

NAME = "jacobi2d"
DIMS = 2
HALO = 1
FLOPS_PER_POINT = 5.0


def update(ext: jax.Array, h: int) -> jax.Array:
    c = ext[h:-h, h:-h]
    n = ext[: -2 * h, h:-h]
    s = ext[2 * h :, h:-h]
    w = ext[h:-h, : -2 * h]
    e = ext[h:-h, 2 * h :]
    return 0.2 * (c + n + s + e + w)


def step(x, block_rows=None, interpret=None):
    return stencil2d_call(x, update, HALO, block_rows, interpret)
