"""Serving substrate: KV caches (incl. MLA latents, SWA rings, SSM states),
prefill/decode steps, batched generation."""

from .kvcache import init_caches  # noqa: F401
from .serve_step import make_decode_step, make_prefill, generate  # noqa: F401
