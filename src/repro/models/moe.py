"""Mixture-of-Experts layer: top-k routing, capacity-bounded dispatch,
optional shared experts (DeepSeek), load-balancing aux loss.

Dispatch is *gather/scatter*-based rather than the GShard one-hot einsum:
tokens are expanded k-fold, ranked within their expert by a cumulative
count, and scattered into a dense ``(E, C, d)`` buffer (rank >= capacity is
dropped, standard capacity-style routing). This keeps dispatch FLOPs ~0 (it
is data movement, which is what it is on hardware) instead of the
``O(T*E*C*d)`` matmul the one-hot formulation pays -- on the dry-run
roofline this shows up as a useful-flops ratio close to 1.

Routing is *grouped by batch row* (G = B groups of S tokens): the rank
cumsum and the scatter then stay local to each data shard, so GSPMD only
needs the expert all-to-all itself, not a token-axis gather. Decode steps
(S == 1) route the whole batch as one group instead so per-expert capacity
never rounds down to nothing.

Sharding: the expert dimension E of the weights is sharded over the
``model`` mesh axis (EP); the scatter/gather lowers to the expected
all-to-all-like exchange.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import dense_init, mlp, mlp_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg: ArchConfig, dtype) -> Dict:
    m = cfg.moe
    d = cfg.d_model
    k_router, k_exp, k_shared = jax.random.split(key, 3)

    def expert_stack(key, n):
        keys = jax.random.split(key, n)
        return jax.vmap(lambda kk: mlp_init(kk, d, m.d_ff, cfg.act, dtype))(keys)

    p = {
        "router": dense_init(k_router, (d, m.n_experts), dtype, scale=0.02),
        "experts": expert_stack(k_exp, m.n_experts),  # leaves: (E, ...)
    }
    if m.n_shared:
        p["shared"] = mlp_init(k_shared, d, m.d_ff * m.n_shared, cfg.act, dtype)
    return p


def _dispatch_group(cfg: ArchConfig, xg: jnp.ndarray, gates, idx, cap: int):
    """One routing group. xg: (Tg, d); gates/idx: (Tg, k).

    Returns (buf (E, cap, d), slot (Tg*k,), keep (Tg*k,), flat_t (Tg*k,),
    flat_g (Tg*k,)).
    """
    m = cfg.moe
    tg, d = xg.shape
    e, k = m.n_experts, m.top_k
    flat_e = idx.reshape(-1)
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(tg), k)
    # rank of each expanded token within its expert (order = token order)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (Tg*k, E)
    prior = jnp.cumsum(onehot, axis=0) - onehot  # same-expert tokens before
    rank = jnp.take_along_axis(prior, flat_e[:, None], axis=1)[:, 0]
    keep = rank < cap
    slot = flat_e * cap + jnp.where(keep, rank, 0)
    buf = jnp.zeros((e * cap, d), xg.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xg[flat_t], 0))
    return buf.reshape(e, cap, d), slot, keep, flat_t, flat_g


def _combine_group(expert_out_flat, slot, keep, flat_t, flat_g, tg, d):
    gathered = expert_out_flat[slot] * jnp.where(keep, flat_g, 0.0)[:, None].astype(
        expert_out_flat.dtype
    )
    return jnp.zeros((tg, d), expert_out_flat.dtype).at[flat_t].add(gathered)


def moe_apply(
    params: Dict, cfg: ArchConfig, x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar f32)."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    # group by batch row (stays local to the data shard); decode: one group
    g, tg = (b, s) if s > 1 else (1, b)
    xg = x.reshape(g, tg, d)

    logits = jnp.einsum("gtd,de->gte", xg, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style): E * <f_e> . <p_e>
    me = probs.mean(axis=(0, 1))
    fe = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32).mean(axis=(0, 1))
    aux = e * jnp.sum(fe * me) * m.router_aux_weight

    cap = int(max(1, round(tg * k / e * m.capacity_factor)))

    buf, slot, keep, flat_t, flat_g = jax.vmap(
        lambda xx, gg, ii: _dispatch_group(cfg, xx, gg, ii, cap)
    )(xg, gates, idx)
    # buf: (G, E, cap, d) -> experts see all groups' slices: (E, G*cap, d)
    ein = jnp.moveaxis(buf, 1, 0).reshape(e, g * cap, d)
    eout = jax.vmap(lambda p, h: mlp(p, h, cfg.act))(params["experts"], ein)
    eout = jnp.moveaxis(eout.reshape(e, g, cap, d), 1, 0).reshape(g, e * cap, d)

    y = jax.vmap(lambda eo, sl, kp, ft, fg: _combine_group(eo, sl, kp, ft, fg, tg, d))(
        eout, slot, keep, flat_t, flat_g
    )

    if m.n_shared:
        y = y + mlp(params["shared"], xg, cfg.act)
    return y.reshape(b, s, d), aux
