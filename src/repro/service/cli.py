"""Command-line front end for the codesign query service.

Quickstart (first call sweeps once and persists the artifact; every later
call -- any frequency mix, budget, what-if -- is a warm re-reduction):

    python -m repro.service.cli query --stencil heat2d --max-area 450
    python -m repro.service.cli query --freq heat2d=3 --freq jacobi2d=1 \\
        --top-k 5 --pareto --fix n_sm=16
    python -m repro.service.cli build --downsample 4     # pre-warm a store
    python -m repro.service.cli build --gpu titanx       # second GPU target
    python -m repro.service.cli ls

LM workloads (op-graph cells over mesh plans; see docs/lm_codesign.md --
area IS the chip count, so --max-area is a chip budget):

    python -m repro.service.cli build --workload lm --chips 256
    python -m repro.service.cli query --workload lm \\
        --freq llama3-8b:decode=1 --max-area 64 --top-k 3

Fleet serving (gateway over every stored artifact; see docs/serving.md):

    python -m repro.service.cli serve --port 8932
    python -m repro.service.cli query --url http://127.0.0.1:8932 \\
        --gpu titanx --stencil heat2d --max-area 450
    python -m repro.service.cli query --url http://127.0.0.1:8932 \\
        --gpu tpu_v5e --workload lm --freq llama3-8b:decode=1

Fleet portfolios (K designs + heterogeneity-aware routing; see
docs/portfolio.md):

    python -m repro.service.cli portfolio --gpu titanx --k 2 --budget 900
    python -m repro.service.cli route heat2d --gpu titanx
    python -m repro.service.cli route heat2d --url http://127.0.0.1:8932 \\
        --gpu titanx

The store location is ``--store``, else ``$REPRO_STORE``, else
``~/.cache/repro/codesign-store``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import urllib.error

import numpy as np

from .query import QueryRequest
from .server import CodesignServer
from .store import ArtifactStore
from .wire import RemoteError

DEFAULT_STORE = os.environ.get(
    "REPRO_STORE", os.path.join(os.path.expanduser("~"), ".cache", "repro", "codesign-store")
)

def _gpu_names():
    """Buildable GPU targets (paper §IV.B GTX-980 + §V Titan X) -- read
    from THE registry (`timemodel.GPUS_BY_NAME`, a numpy-only import) so
    the CLI knobs can never drift from the families the model knows."""
    from repro.core.timemodel import GPUS_BY_NAME

    return sorted(GPUS_BY_NAME)


def _gpu(name: str):
    from repro.core.timemodel import GPUS_BY_NAME

    try:
        return GPUS_BY_NAME[name]
    except KeyError:
        # reached only on in-process paths: with --url the name is a
        # routing selector and never resolves to constants here
        raise _die(
            f"unknown GPU target {name!r} (in-process builds support "
            f"{_gpu_names()}; calibrated names like 'gtx980-cal' route "
            "only through a gateway, via --url)"
        ) from None


def _die(message: str) -> "SystemExit":
    """Clear one-line failure on stderr, exit status 2 -- never a
    traceback (the CI smoke lane asserts this)."""
    print(f"error: {message}", file=sys.stderr)
    return SystemExit(2)


def _add_server_args(p: argparse.ArgumentParser) -> None:
    p.add_argument("--store", default=DEFAULT_STORE, help="artifact store directory")
    p.add_argument("--gpu", default=None,
                   help=f"GPU target constants, one of {_gpu_names()} "
                        "(default gtx980); with --workload lm, the accelerator "
                        "name stamped on the artifact (default tpu_v5e); with "
                        "--url, the routing selector instead -- any served "
                        "name, incl. calibrated ones like 'gtx980-cal'")
    p.add_argument("--workload", default=None, metavar="FAMILY",
                   help="cell family to build/query: 'lm' sweeps LM op-graph "
                        "cells over mesh plans (docs/lm_codesign.md); default "
                        "is the paper's stencil workload. With --url, the "
                        "workload-name routing selector")
    p.add_argument("--arch", action="append", metavar="NAME",
                   help="with --workload lm: model config to include, e.g. "
                        "llama3-8b (repeatable; default llama3-8b + "
                        "mixtral-8x22b)")
    p.add_argument("--chips", type=int, default=512,
                   help="with --workload lm: chip budget bounding the mesh "
                        "factorization space (default 512, the smallest "
                        "budget where every default cell fits)")
    p.add_argument("--max-hw-area", type=float, default=650.0,
                   help="hardware-space enumeration budget (mm^2)")
    p.add_argument("--downsample", type=int, default=1,
                   help="keep every Nth hardware point (quick demos)")
    p.add_argument(
        "--engine", choices=("auto", "jax", "sharded", "numpy"), default="auto"
    )
    p.add_argument(
        "--devices", type=int, default=None,
        help="sharded engine: first N attached devices (default: all)",
    )


def _server(args):
    """In-process server for the requested cell family (the --url path
    never gets here; there the flags become routing selectors)."""
    if args.workload is not None and args.workload != "lm":
        raise _die(
            f"in-process --workload supports 'lm' (got {args.workload!r}); "
            "other workload names are routing selectors for --url queries"
        )
    if args.workload != "lm" and (args.arch or args.chips != 512):
        raise _die("--arch/--chips only apply to --workload lm")
    if args.workload == "lm":
        from repro.core.lmcells import LM_GPU_NAME, lm_workload

        from .server import LMServer

        kw = {}
        if args.arch:
            kw["workload"] = lm_workload(archs=tuple(args.arch))
        return LMServer(
            ArtifactStore(args.store),
            max_chips=args.chips,
            downsample=args.downsample,
            engine=args.engine,
            gpu_name=args.gpu or LM_GPU_NAME,
            batch_window=0.0,
            **kw,
        )
    return CodesignServer(
        ArtifactStore(args.store),
        gpu=_gpu(args.gpu or "gtx980"),
        max_area=args.max_hw_area,
        downsample=args.downsample,
        engine=args.engine,
        devices=args.devices,
        batch_window=0.0,  # CLI is single-threaded; no rendezvous needed
    )


def _freqs(args):
    freqs = {}
    for name in args.stencil or []:
        freqs[name] = freqs.get(name, 0.0) + 1.0
    for spec in args.freq or []:
        name, _, w = spec.partition("=")
        if not w:
            raise SystemExit(f"--freq wants name=weight, got {spec!r}")
        freqs[name] = freqs.get(name, 0.0) + float(w)
    return freqs or None


def _fix(args):
    fix = {}
    for spec in args.fix or []:
        name, _, v = spec.partition("=")
        if not v:
            raise SystemExit(f"--fix wants param=value, got {spec!r}")
        fix[name] = float(v)
    return fix or None


def _print_response(resp, out, total_hw=None) -> None:
    """Shared human-readable rendering for the in-process and --url paths
    (same QueryResponse object either way)."""
    b = out["best"]
    if resp.best_index < 0:
        print("no design satisfies the requested constraints "
              "(budget/fix select an empty subspace)")
        return
    if "n_sm" in b:  # stencil sweeps keep the paper's design-point layout
        print(f"best:  n_SM={b['n_sm']:3d} n_V={b['n_v']:4d} M_SM={b['m_sm']:4.0f}kB "
              f"area={b['area']:6.1f}mm^2  {b['gflops']:8.1f} GFLOP/s")
        for r in resp.top_k[1:]:
            print(f"       n_SM={r['n_sm']:3d} n_V={r['n_v']:4d} M_SM={r['m_sm']:4.0f}kB "
                  f"area={r['area']:6.1f}mm^2  {r['gflops']:8.1f} GFLOP/s")
    else:  # generic design points (LM: pod/data/model/chips)
        def _row(point):
            pairs = " ".join(
                f"{k}={point[k]:g}" for k in point
                if k not in ("index", "gflops", "weighted_time")
            )
            return f"{pairs}  {point['gflops']:10.1f} GFLOP/s"

        print(f"best:  {_row({**resp.best_point, 'gflops': b['gflops']})}")
        for r in resp.top_k[1:]:
            print(f"       {_row(r)}")
    if "pareto" in out:
        of = f" of {total_hw}" if total_hw else ""
        print(f"pareto front: {out['pareto']['count']}{of} designs")
    if "what_if" in out:
        w = out["what_if"]
        print(f"what-if delta vs unrestricted best: {w['delta_gflops']:+.1f} GFLOP/s")


def _load_batch_file(path: str):
    """A --batch-file is a JSON array of ``{"artifact"?, "route"?,
    "request"}`` objects (the /v1/query_many elements, verbatim)."""
    try:
        with open(path) as f:
            items = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise _die(f"cannot read batch file {path!r}: {e}")
    if not isinstance(items, list) or not items:
        raise _die(f"batch file {path!r} must hold a non-empty JSON array")
    triples = []
    for i, obj in enumerate(items):
        if not isinstance(obj, dict) or "request" not in obj:
            raise _die(f"batch file entry {i} must be an object with a 'request'")
        try:
            triples.append(
                (QueryRequest(**obj["request"]), obj.get("artifact"), obj.get("route"))
            )
        except TypeError as e:
            raise _die(f"batch file entry {i}: {e}")
    return triples


def cmd_query_batch(args) -> None:
    """One /v1/query_many round trip; per-query results (answers or
    structured errors) print as a JSON array in input order."""
    from .client import GatewayClient

    if not args.url:
        raise _die("--batch-file requires --url (the batched endpoint is "
                   "a gateway feature)")
    # the batch file is the whole question: silently ignoring query-shaping
    # flags would run different constraints than the user typed
    superseded = {
        "--stencil": args.stencil, "--freq": args.freq, "--fix": args.fix,
        "--artifact": args.artifact, "--gpu": args.gpu,
        "--workload": args.workload, "--arch": args.arch,
        "--pareto": args.pareto or None,
        "--max-area": None if args.max_area == np.inf else args.max_area,
        "--min-area": args.min_area or None,
        "--top-k": None if args.top_k == 1 else args.top_k,
    }
    clashing = sorted(flag for flag, v in superseded.items() if v)
    if clashing:
        raise _die(
            f"{', '.join(clashing)} cannot be combined with --batch-file; "
            "put the constraints in each batch entry's 'request' instead"
        )
    triples = _load_batch_file(args.batch_file)
    client = GatewayClient(args.url)
    t0 = time.perf_counter()
    try:
        results = client.query_many(triples)
    except RemoteError as e:
        raise _die(f"gateway refused the batch: {e}")
    except urllib.error.URLError as e:
        raise _die(f"cannot reach gateway at {args.url}: {e.reason}")
    dt = time.perf_counter() - t0
    out = []
    for r in results:
        if isinstance(r, RemoteError):
            out.append({"ok": False,
                        "error": {"code": r.code, "message": r.message}})
        else:
            feasible = r.best_index >= 0
            out.append({
                "ok": True,
                "artifact_key": r.artifact_key,
                "feasible": feasible,
                "best": {**r.best_point, "index": r.best_index,
                         "gflops": r.best_gflops} if feasible else None,
                "top_k": r.top_k,
            })
    json.dump({"batch_s": round(dt, 4), "results": out}, sys.stdout,
              indent=1, default=float)
    sys.stdout.write("\n")


def cmd_query(args) -> None:
    if args.batch_file:
        cmd_query_batch(args)
        return
    req = QueryRequest(
        freqs=_freqs(args),
        max_area=args.max_area,
        min_area=args.min_area,
        top_k=args.top_k,
        pareto=args.pareto,
        fix=_fix(args),
    )
    total_hw = None
    if args.url:
        from .client import GatewayClient

        client = GatewayClient(args.url)
        route = None
        if args.artifact is None:
            route = {}
            if args.gpu is not None:
                route["gpu"] = args.gpu
            if args.workload is not None:
                route["workload"] = args.workload
            route = route or None
        t0 = time.perf_counter()
        try:
            resp = client.query(req, artifact=args.artifact, route=route)
        except RemoteError as e:
            raise _die(f"gateway refused the query: {e}")
        except urllib.error.URLError as e:
            raise _die(f"cannot reach gateway at {args.url}: {e.reason}")
        dt = time.perf_counter() - t0
        origin = f"via {args.url}"
    else:
        if args.artifact:
            raise _die("--artifact only applies to --url (gateway) queries")
        srv = _server(args)
        origin = "warm" if srv.warm else "cold build"
        total_hw = len(srv.hw)
        t0 = time.perf_counter()
        resp = srv.query(req)
        dt = time.perf_counter() - t0
    feasible = resp.best_index >= 0
    out = {
        "artifact_key": resp.artifact_key,
        "origin": origin,
        "query_s": round(dt, 4),
        "feasible": feasible,
        "best": {**resp.best_point, "index": resp.best_index,
                 "gflops": resp.best_gflops,
                 "weighted_time_s": resp.best_weighted_time} if feasible else None,
        "top_k": resp.top_k,
    }
    if resp.pareto_indices is not None:
        out["pareto"] = {
            "count": int(resp.pareto_indices.size),
            "indices": [int(i) for i in resp.pareto_indices],
        }
    if resp.baseline_best_index is not None:
        out["what_if"] = {
            "baseline_best_index": resp.baseline_best_index,
            "baseline_best_gflops": resp.baseline_best_gflops,
            "delta_gflops": resp.best_gflops - resp.baseline_best_gflops,
        }
    if args.json:
        json.dump(out, f := sys.stdout, indent=1, default=float)
        f.write("\n")
        return
    print(f"artifact {resp.artifact_key} ({origin}), query {dt*1e3:.1f} ms")
    _print_response(resp, out, total_hw)


def cmd_build(args) -> None:
    from .errors import GatewayError

    srv = _server(args)
    t0 = time.perf_counter()
    try:
        srv.ensure_artifact()
    except GatewayError as e:
        # structured serving-layer failures (e.g. build_lock_timeout when
        # another process holds the build flock past REPRO_LOCK_TIMEOUT_S):
        # one line + exit 2, never a traceback
        raise _die(f"{e.code}: {e}")
    gpu_name = srv.gpu_name if hasattr(srv, "gpu_name") else srv.gpu.name
    print(f"artifact {srv.key}: "
          f"{'already stored' if srv.stats['artifact_loads'] else 'built'} "
          f"({time.perf_counter()-t0:.1f}s, {len(srv.hw)} hw points, "
          f"{len(srv.workload.cells)} cells, gpu={gpu_name})")


def cmd_portfolio(args) -> None:
    """Optimize + persist a K-design fleet portfolio over a sweep
    artifact, building the sweep first on miss (docs/portfolio.md)."""
    from .errors import GatewayError
    from .portfolio import build_portfolio

    srv = _server(args)
    try:
        srv.ensure_artifact()
    except GatewayError as e:
        raise _die(f"{e.code}: {e}")
    store = ArtifactStore(args.store)
    known = set(store.keys())
    t0 = time.perf_counter()
    try:
        art, result = build_portfolio(
            store, srv.key, args.k, args.budget,
            objective=args.objective, engine=args.portfolio_engine,
        )
    except ValueError as e:
        raise _die(str(e))
    members = ",".join(str(m) for m in result.members)
    print(f"portfolio {art.key}: "
          f"{'already stored' if art.key in known else 'built'} "
          f"({time.perf_counter()-t0:.1f}s, k={result.k} "
          f"objective={result.objective} budget={result.budget:g} "
          f"members=[{members}] fleet={result.fleet_gflops:.1f} GFLOP/s "
          f"area={result.total_area:.1f})")


def cmd_route(args) -> None:
    """Route one workload cell-group through a stored portfolio (over
    HTTP with --url, else in-process through a Gateway)."""
    from .portfolio import RouteRequest

    req = RouteRequest(cell=args.cell)
    selector = {}
    if args.gpu is not None:
        selector["gpu"] = args.gpu
    if args.workload is not None:
        selector["workload"] = args.workload
    route = (selector or None) if args.artifact is None else None
    if args.url:
        from .client import GatewayClient

        client = GatewayClient(args.url)
        try:
            resp = client.route(req, artifact=args.artifact, route=route)
        except RemoteError as e:
            raise _die(f"gateway refused the route: {e}")
        except urllib.error.URLError as e:
            raise _die(f"cannot reach gateway at {args.url}: {e.reason}")
        origin = f"via {args.url}"
    else:
        from .errors import GatewayError
        from .gateway import Gateway

        try:
            gw = Gateway([args.store], batch_window=0.0)
        except FileNotFoundError as e:
            raise _die(str(e))
        try:
            resp = gw.route(req, artifact=args.artifact, route=route)
        except GatewayError as e:
            raise _die(f"{e.code}: {e}")
        origin = "in-process"
    out = {
        "portfolio_key": resp.portfolio_key,
        "sweep_key": resp.sweep_key,
        "cell": resp.cell,
        "member_slot": resp.member_slot,
        "hw_index": resp.hw_index,
        "point": resp.point,
        "time_s": resp.time_s,
        "gflops": resp.gflops,
        "degraded": resp.degraded,
        "fallback_from": list(resp.fallback_from),
    }
    if args.json:
        json.dump(out, sys.stdout, indent=1, default=float)
        sys.stdout.write("\n")
        return
    point = " ".join(f"{k}={v:g}" for k, v in resp.point.items() if k != "index")
    flag = (f"  [degraded: fell back from hw {list(resp.fallback_from)}]"
            if resp.degraded else "")
    print(f"portfolio {resp.portfolio_key} ({origin})")
    print(f"{resp.cell} -> member {resp.member_slot} (hw {resp.hw_index}): "
          f"{point}  {resp.gflops:.1f} GFLOP/s{flag}")


def cmd_ls(args) -> None:
    store = ArtifactStore(args.store)
    rows = store.entries()
    if not rows:
        print(f"(no artifacts under {store.root})")
        return
    for r in rows:
        kind = r.get("kind", "sweep")
        if kind != "sweep":
            print(f"{r['key']}  v{r['format_version']}  kind={kind}  "
                  + " ".join(f"{k}={v}" for k, v in sorted(r.items())
                             if k not in ("key", "format_version", "kind")))
            continue
        if r.get("family", "stencil") == "lm":
            groups = ",".join(r.get("models") or []) or "?"
            ops = ",".join(r.get("ops") or [])
            print(f"{r['key']}  v{r['format_version']}  {r['workload']:16s} "
                  f"gpu={r['gpu']:8s} {r['cells']:4d} cells x {r['hw']:6d} hw  "
                  f"engine={r['engine']}  lm[{groups}: {ops}]")
            continue
        print(f"{r['key']}  v{r['format_version']}  {r['workload']:16s} "
              f"gpu={r['gpu']:8s} {r['cells']:4d} cells x {r['hw']:6d} hw  "
              f"engine={r['engine']}  [{','.join(r['stencils'])}]")


def cmd_upgrade(args) -> None:
    """Backfill routing blocks / kind tags on manifests written by older
    writers (pre-gateway). Content keys never move (the key hashes the
    question spec, not the manifest bytes)."""
    roots = [args.store] + (args.root or [])
    total = stored = 0
    for root in roots:
        try:
            store = ArtifactStore(root, create=False)
        except FileNotFoundError as e:
            raise _die(str(e))
        upgraded = store.upgrade_manifests()
        total += len(upgraded)
        stored += len(store.keys())
        for key in upgraded:
            print(f"upgraded {key}  ({root})")
    print(f"{total} manifest(s) upgraded, {stored} total")


def cmd_gc(args) -> None:
    """Kind-aware artifact retention over store root(s): the default
    (``--dry-run``) prints the deterministic eviction plan as canonical
    JSON; ``--apply`` executes it via :meth:`ArtifactStore.delete`.
    Telemetry snapshots age out first; a sweep referenced by a stored
    portfolio member is never evicted (docs/serving.md)."""
    from .usage import UsageLedger, retention_plan

    roots = [args.store] + (args.root or [])
    out = []
    for root in roots:
        try:
            store = ArtifactStore(root, create=False)
        except FileNotFoundError as e:
            raise _die(str(e))
        # routing rows don't carry payload fields; decorate the two kinds
        # whose plan inputs live there (telemetry age, portfolio member)
        entries = []
        for row in store.entries():
            kind = row.get("kind", "sweep")
            if kind in ("telemetry", "portfolio"):
                art = store.get(row["key"])
                if art is not None:
                    if kind == "telemetry":
                        row = {**row,
                               "collected_at": art.payload.get("collected_at")}
                    else:
                        row = {**row, "sweep_key": art.payload.get("sweep_key")}
            entries.append(row)
        try:
            plan = retention_plan(
                entries,
                UsageLedger(root).snapshot(),
                telemetry_cap=args.telemetry_cap,
                max_artifacts=args.max_artifacts,
            )
        except ValueError as e:
            raise _die(str(e))
        deleted = []
        if args.apply:
            for e in plan["evict"]:
                if store.delete(e["key"]):
                    deleted.append(e["key"])
        out.append({"root": store.root, "plan": plan,
                    "applied": bool(args.apply), "deleted": deleted})
    json.dump(out, sys.stdout, indent=1, sort_keys=True)
    sys.stdout.write("\n")


def cmd_serve(args) -> None:
    """Run the fleet gateway over every artifact under the store root(s).

    Exits 2 with a one-line message (no traceback) when a root is missing
    or holds no artifacts -- a gateway with nothing to serve is a
    misconfiguration, not a valid idle state."""
    from repro.obs import configure_logging

    from .gateway import Gateway, serve_http

    # default quiet: WARNING keeps per-request access lines (DEBUG) and
    # lifecycle notes (INFO) off the console the smoke lane parses
    configure_logging(args.log_level)

    # the default store joins the root list only when no root was named
    # explicitly: `serve --root /data/fleet` must not die because the
    # default cache dir was never created on this host
    roots = ([args.store] if args.store else []) + (args.root or [])
    if not roots:
        roots = [DEFAULT_STORE]
    if args.no_resilience:
        resilience = None
    else:
        from .resilience import GatewayResilience

        resilience = GatewayResilience(
            global_rate=args.rate_limit,
            client_rate=args.client_rate_limit,
            max_inflight=args.max_inflight,
            breaker_threshold=args.breaker_threshold,
            breaker_cooldown_s=args.breaker_cooldown,
        )
    try:
        gw = Gateway(
            roots,
            pool_size=args.pool_size,
            batch_window=args.batch_window,
            telemetry_interval=args.telemetry_interval,
            resilience=resilience,
            usage_flush_interval=args.usage_flush_interval,
            telemetry_cap=args.telemetry_cap,
        )
    except FileNotFoundError as e:
        raise _die(str(e))
    if len(gw) == 0:
        raise _die(
            f"no artifacts under {', '.join(roots)}; build one first: "
            "python -m repro.service.cli build --store <root>"
        )
    httpd = serve_http(gw, host=args.host, port=args.port)
    host, port = httpd.server_address[:2]
    print(f"gateway: {len(gw)} artifact(s) from {len(roots)} store root(s)")
    for row in gw.entries():
        if row.get("kind", "sweep") != "sweep":
            print(f"  {row['key']}  kind={row['kind']}  "
                  f"gpu={row.get('gpu', '?')}")
            continue
        cells = row.get("stencils") or row.get("models") or []
        print(f"  {row['key']}  gpu={row['gpu']}  {row['cells']}x{row['hw']}  "
              f"[{','.join(cells)}]")
    # machine-parseable last line: the smoke lane reads the bound port here
    print(f"serving on http://{host}:{port}", flush=True)
    try:
        httpd.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        gw.flush_usage()  # buffered ledger deltas survive the shutdown
        httpd.server_close()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="repro.service.cli", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    q = sub.add_parser("query", help="answer a codesign query (sweeps on first miss)")
    _add_server_args(q)
    q.add_argument("--url", default=None, metavar="URL",
                   help="query a running gateway over HTTP instead of "
                        "in-process (e.g. http://127.0.0.1:8932)")
    q.add_argument("--artifact", default=None, metavar="KEY",
                   help="with --url: pin the artifact content key to query")
    q.add_argument("--batch-file", default=None, metavar="FILE",
                   help="with --url: JSON array of {artifact?, route?, request} "
                        "objects sent as ONE /v1/query_many round trip")
    q.add_argument("--stencil", action="append",
                   help="cell group to weight 1.0 (repeatable): a stencil "
                        "name, or for LM artifacts a model, op, or model:op")
    q.add_argument("--freq", action="append", metavar="NAME=W",
                   help="explicit cell-group weight (repeatable)")
    q.add_argument("--max-area", type=float, default=np.inf,
                   help="area budget for the answer (mm^2; for LM sweeps "
                        "area IS the chip count, so this is a chip budget)")
    q.add_argument("--min-area", type=float, default=0.0)
    q.add_argument("--top-k", type=int, default=1)
    q.add_argument("--pareto", action="store_true", help="include the Pareto front")
    q.add_argument("--fix", action="append", metavar="PARAM=VALUE",
                   help="what-if subspace, e.g. n_sm=16 (repeatable)")
    q.add_argument("--json", action="store_true", help="machine-readable output")
    q.set_defaults(fn=cmd_query)

    b = sub.add_parser("build", help="pre-warm the default paper-workload artifact")
    _add_server_args(b)
    b.set_defaults(fn=cmd_build)

    pf = sub.add_parser(
        "portfolio",
        help="optimize + persist a K-design fleet portfolio over a sweep "
             "(docs/portfolio.md)",
    )
    _add_server_args(pf)
    pf.add_argument("--k", type=int, default=2,
                    help="max designs in the fleet (sizes 1..K are "
                         "searched; default %(default)s)")
    pf.add_argument("--budget", type=float, required=True,
                    help="total fleet area budget summed over the chosen "
                         "members (mm^2; chips for LM sweeps)")
    pf.add_argument("--objective", choices=("density", "throughput"),
                    default="density",
                    help="density = fleet GFLOP/s per mm^2 of member area "
                         "(default); throughput = fleet GFLOP/s (K=1 "
                         "reproduces the single-design optimum exactly)")
    pf.add_argument("--portfolio-engine", choices=("numpy", "jax"),
                    default="numpy",
                    help="subset-scoring engine (the numpy oracle is the "
                         "reference; jax is the jitted fused scorer)")
    pf.set_defaults(fn=cmd_portfolio)

    rt = sub.add_parser(
        "route",
        help="route a workload cell-group through a stored portfolio",
    )
    rt.add_argument("cell",
                    help="cell-group label: a stencil name, or model:op "
                         "for LM sweeps")
    rt.add_argument("--store", default=DEFAULT_STORE)
    rt.add_argument("--url", default=None, metavar="URL",
                    help="route through a running gateway over HTTP "
                         "instead of in-process")
    rt.add_argument("--artifact", default=None, metavar="KEY",
                    help="pin the portfolio content key to route through")
    rt.add_argument("--gpu", default=None,
                    help="routing selector matching the portfolio's "
                         "inherited gpu tag")
    rt.add_argument("--workload", default=None,
                    help="routing selector matching the portfolio's "
                         "inherited workload tag")
    rt.add_argument("--json", action="store_true",
                    help="machine-readable output")
    rt.set_defaults(fn=cmd_route)

    ls = sub.add_parser("ls", help="list stored artifacts")
    ls.add_argument("--store", default=DEFAULT_STORE)
    ls.set_defaults(fn=cmd_ls)

    up = sub.add_parser(
        "upgrade",
        help="backfill routing/kind on manifests from older writers "
             "(content keys unchanged)",
    )
    up.add_argument("--store", default=DEFAULT_STORE)
    up.add_argument("--root", action="append", metavar="DIR",
                    help="additional store root (repeatable)")
    up.set_defaults(fn=cmd_upgrade)

    s = sub.add_parser(
        "serve", help="HTTP gateway over every stored artifact (docs/serving.md)"
    )
    s.add_argument("--store", default=None,
                   help=f"artifact store directory (default {DEFAULT_STORE} "
                        "unless --root is given)")
    s.add_argument("--root", action="append", metavar="DIR",
                   help="additional store root (repeatable)")
    s.add_argument("--host", default="127.0.0.1")
    s.add_argument("--port", type=int, default=8932,
                   help="TCP port (0 picks a free one and prints it)")
    s.add_argument("--pool-size", type=int, default=8,
                   help="max resident per-artifact servers (LRU beyond)")
    s.add_argument("--batch-window", type=float, default=0.002,
                   help="per-artifact microbatch rendezvous window, seconds")
    s.add_argument("--log-level", default="warning",
                   choices=("debug", "info", "warning", "error"),
                   help="structured-log verbosity on stderr (JSON lines; "
                        "debug includes per-request access logs; default "
                        "warning = quiet)")
    s.add_argument("--rate-limit", type=float, default=0.0, metavar="QPS",
                   help="global admission rate for the query routes in "
                        "requests/s (0 = unlimited); over-budget requests "
                        "get HTTP 429 + Retry-After")
    s.add_argument("--client-rate-limit", type=float, default=0.0,
                   metavar="QPS",
                   help="per-client admission rate (clients keyed by the "
                        "X-Repro-Client header, else remote address; "
                        "0 = unlimited)")
    s.add_argument("--max-inflight", type=int, default=128, metavar="N",
                   help="shed watermark: concurrent query requests beyond "
                        "this get HTTP 503 code=shed (0 = unlimited; "
                        "default %(default)s)")
    s.add_argument("--breaker-threshold", type=int, default=5, metavar="N",
                   help="consecutive raw failures that open a per-artifact "
                        "circuit breaker (default %(default)s)")
    s.add_argument("--breaker-cooldown", type=float, default=30.0,
                   metavar="SECONDS",
                   help="open-circuit cooldown before a half-open probe "
                        "(default %(default)s)")
    s.add_argument("--no-resilience", action="store_true",
                   help="disable admission control and circuit breakers "
                        "entirely (deadlines still apply)")
    s.add_argument("--telemetry-interval", type=float, default=0.0,
                   help="seconds between persisted per-artifact telemetry "
                        "snapshots (kind: 'telemetry' store artifacts; "
                        "0 = off, the default)")
    s.add_argument("--telemetry-cap", type=int, default=32, metavar="N",
                   help="retained telemetry snapshots per store root; older "
                        "ones are pruned after each persist (default "
                        "%(default)s)")
    s.add_argument("--usage-flush-interval", type=float, default=60.0,
                   metavar="SECONDS",
                   help="seconds between usage-ledger flushes to the "
                        ".usage-ledger.json beside each store root "
                        "(default %(default)s)")
    s.set_defaults(fn=cmd_serve)

    g = sub.add_parser(
        "gc",
        help="plan / apply kind-aware artifact retention over a store "
             "(docs/serving.md)",
    )
    g.add_argument("--store", default=DEFAULT_STORE)
    g.add_argument("--root", action="append", metavar="DIR",
                   help="additional store root (repeatable)")
    mx = g.add_mutually_exclusive_group()
    mx.add_argument("--dry-run", action="store_true",
                    help="print the eviction plan without deleting "
                         "(the default)")
    mx.add_argument("--apply", action="store_true",
                    help="execute the plan (deletes artifacts)")
    g.add_argument("--telemetry-cap", type=int, default=32, metavar="N",
                   help="retained telemetry snapshots per root, newest "
                        "first (default %(default)s)")
    g.add_argument("--max-artifacts", type=int, default=None, metavar="N",
                   help="optional total cap per root: evict the coldest "
                        "unprotected artifacts beyond it (ledger hits, "
                        "then last access, then kind)")
    g.set_defaults(fn=cmd_gc)

    args = ap.parse_args(argv)
    args.fn(args)


if __name__ == "__main__":
    main()
