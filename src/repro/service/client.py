"""Thin HTTP client for a codesign gateway (stdlib ``urllib`` only).

The client is a pure transport shim: it encodes with
:mod:`repro.service.wire`, POSTs, and decodes -- so a
:class:`~repro.service.query.QueryResponse` obtained here is the same
object (field for field, and on the wire byte for byte) the in-process
:class:`~repro.service.server.CodesignServer` would have returned.

    from repro.service import GatewayClient, QueryRequest

    c = GatewayClient("http://127.0.0.1:8932")
    c.artifacts()                                   # routing index rows
    c.query(QueryRequest(freqs={"heat2d": 1.0}),    # routed by selector
            route={"gpu": "titanx"})

Structured gateway failures raise :class:`repro.service.wire.RemoteError`
with the server's error ``code`` (``unknown_artifact``, ``bad_request``,
``ambiguous_route``, ``internal``); transport-level failures surface as
the usual ``urllib.error.URLError``.
"""

from __future__ import annotations

import json
import urllib.error
import urllib.request
from typing import Any, Dict, List, Mapping, Optional

from . import wire
from .query import QueryRequest, QueryResponse

__all__ = ["GatewayClient"]


class GatewayClient:
    """Client for one gateway base URL (e.g. ``http://host:port``)."""

    def __init__(self, base_url: str, timeout: float = 30.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = float(timeout)
        self._last_status = 0  # HTTP status of the most recent call

    # ---- transport --------------------------------------------------------
    def _http(self, path: str, body: Optional[bytes] = None) -> bytes:
        """One request; returns the raw body. HTTP error statuses still
        carry wire payloads -- the body is returned (not raised) so the
        decoder can surface the server's structured code."""
        req = urllib.request.Request(
            self.base_url + path,
            data=body,
            method="POST" if body is not None else "GET",
            headers={"Content-Type": "application/json"},
        )
        try:
            with urllib.request.urlopen(req, timeout=self.timeout) as resp:
                self._last_status = resp.status
                return resp.read()
        except urllib.error.HTTPError as e:
            self._last_status = e.code
            return e.read()

    def query_bytes(
        self,
        request: QueryRequest,
        artifact: Optional[str] = None,
        route: Optional[Mapping[str, Any]] = None,
    ) -> bytes:
        """The raw response body for one query -- the byte-identity tests'
        entry point (no decode/re-encode in between)."""
        return self._http(
            "/v1/query", wire.encode_request(request, artifact=artifact, route=route)
        )

    # ---- API --------------------------------------------------------------
    def query(
        self,
        request: QueryRequest,
        artifact: Optional[str] = None,
        route: Optional[Mapping[str, Any]] = None,
    ) -> QueryResponse:
        """Answer one request over HTTP; raises
        :class:`~repro.service.wire.RemoteError` on structured failures."""
        body = self.query_bytes(request, artifact=artifact, route=route)
        return wire.decode_response(body, http_status=self._last_status)

    def _json(self, path: str, body: Optional[bytes] = None) -> Dict[str, Any]:
        """GET/POST a JSON endpoint; a non-2xx answer raises the server's
        structured error as :class:`RemoteError` instead of a KeyError on
        the missing success fields."""
        raw = self._http(path, body)
        if not 200 <= self._last_status < 300:
            try:
                err = json.loads(raw).get("error") or {}
            except ValueError:
                err = {}
            raise wire.RemoteError(
                str(err.get("code", "unknown")),
                str(err.get("message", raw[:200].decode("utf-8", "replace"))),
                self._last_status,
            )
        return json.loads(raw)

    def artifacts(self) -> List[Dict[str, Any]]:
        """Routing rows for every artifact the gateway serves."""
        return self._json("/v1/artifacts")["artifacts"]

    def health(self) -> Dict[str, Any]:
        return self._json("/v1/healthz")

    def refresh(self) -> int:
        """Ask the gateway to re-scan its store roots; returns the indexed
        artifact count."""
        return self._json("/v1/refresh", b"")["artifacts"]
