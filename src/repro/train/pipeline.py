"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

Complements the DP/TP/EP rules in ``repro.sharding``: when a model's layers
do not fit even with TP+FSDP, stages of layers are placed on a ``stage``
mesh axis and microbatches stream through with the classic GPipe schedule
(M + S - 1 ticks, bubble fraction (S-1)/(M+S-1)).

TPU-native mapping (DESIGN.md "hardware adaptation"): stage-to-stage
transfers are ``jax.lax.ppermute`` over the stage axis inside a
``shard_map`` -- the ICI-neighbour communication pattern a real pod
pipeline uses -- rather than host-mediated sends.

The schedule is deliberately the simple fill-drain GPipe (not 1F1B):
activations for in-flight microbatches are the caller's remat problem, and
the dry-run measures it like everything else.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["pipeline_apply", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """Idle fraction of the fill-drain schedule."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def pipeline_apply(
    stage_fn: Callable,
    stage_params,
    x: jnp.ndarray,
    mesh: Mesh,
    n_microbatches: int,
    axis: str = "stage",
):
    """Run ``stage_fn`` as an S-stage pipeline over microbatches.

    stage_fn(params_one_stage, h) -> h  applied by every stage in order;
    stage_params: pytree with leading dim S (sharded over ``axis``);
    x: (B, ...) global input; B must divide by n_microbatches.

    Returns stage_{S-1}(... stage_0(x)) with identical semantics to the
    sequential loop (asserted in tests/test_pipeline.py).
    """
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    b = x.shape[0]
    assert b % n_microbatches == 0, (b, n_microbatches)
    mb = b // n_microbatches
    xs = x.reshape(n_microbatches, mb, *x.shape[1:])

    p_spec = jax.tree.map(lambda _: P(axis), stage_params)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(p_spec, P()),  # params split by stage; data replicated
        out_specs=P(),
        check_rep=False,
    )
    def run(params_local, xs_rep):
        # params_local leaves: (1, ...) -- this device's stage
        params_here = jax.tree.map(lambda a: a[0], params_local)
        sidx = jax.lax.axis_index(axis)
        ticks = n_microbatches + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]  # stage i -> i+1

        h0 = jnp.zeros_like(xs_rep[0])
        outs0 = jnp.zeros_like(xs_rep)

        def tick(carry, t):
            h_in, outs = carry
            # stage 0 injects microbatch t (when one is due)
            feed_idx = jnp.clip(t, 0, n_microbatches - 1)
            h_feed = jnp.where(
                (sidx == 0) & (t < n_microbatches),
                xs_rep[feed_idx],
                h_in,
            )
            active = (t >= sidx) & (t < sidx + n_microbatches)
            h_out = jnp.where(active, stage_fn(params_here, h_feed), h_feed)
            # last stage banks microbatch (t - (S-1)) when it completes
            done_idx = jnp.clip(t - (n_stages - 1), 0, n_microbatches - 1)
            bank = (sidx == n_stages - 1) & (t >= n_stages - 1)
            outs = jnp.where(
                bank[None] if bank.ndim else bank,
                outs.at[done_idx].set(h_out),
                outs,
            )
            # shift activations one stage to the right
            h_next = jax.lax.ppermute(h_out, axis, perm)
            return (h_next, outs), None

        (_, outs), _ = jax.lax.scan(tick, (h0, outs0), jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast via masked psum
        outs = jnp.where(sidx == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    out = run(stage_params, xs)
    return out.reshape(b, *x.shape[1:])
