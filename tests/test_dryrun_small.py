"""Dry-run machinery end-to-end on 8 fake devices with reduced configs:
lower + compile + cost/memory/collective analysis for single and multi-pod
tiny meshes. (The full 512-device run is `python -m repro.launch.dryrun`.)"""

import json
import os
import subprocess
import sys

import pytest

# multi-second jit compiles: the fast CI lane deselects these (-m "not slow");
# the weekly scheduled lane (and a bare local `pytest`) still runs them
pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(arch, shape, mesh, outdir):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["REPRO_DRYRUN_DEVICES"] = "8"
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun", "--tiny",
            "--arch", arch, "--shape", shape, "--mesh", mesh,
            "--out", outdir, "--force",
        ],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    path = os.path.join(outdir, mesh, f"{arch}__{shape}.json")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_train_cell_compiles_and_accounts(tmp_path, mesh):
    rec = _run("internlm2-1.8b", "train_4k", mesh, str(tmp_path))
    assert not rec.get("skipped") and "error" not in rec
    assert rec["flops"] > 0
    assert rec["dot_flops_expanded"] > rec["flops"] * 0.5
    assert rec["collective_bytes"] > 0  # DP/TP collectives must exist
    assert "all-reduce" in rec["collectives"]
    assert rec["memory"]["temp_size_in_bytes"] > 0


def test_decode_cell_compiles(tmp_path):
    rec = _run("mixtral-8x22b", "decode_32k", "single", str(tmp_path))
    assert not rec.get("skipped") and "error" not in rec
    assert rec["flops"] > 0


def test_ssm_long_context_runs(tmp_path):
    rec = _run("mamba2-780m", "long_500k", "single", str(tmp_path))
    assert not rec.get("skipped") and "error" not in rec


def test_full_attention_long_context_skips(tmp_path):
    rec = _run("llama3-8b", "long_500k", "single", str(tmp_path))
    assert rec["skipped"] and "quadratic" in rec["reason"]
