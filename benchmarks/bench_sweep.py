"""NumPy chunked sweep vs compiled JAX sweep engines on the Fig.-3 workload.

Times the full eq.-(18) solve (every workload cell x every feasible
hardware point) once per engine -- NumPy oracle, single-device JAX, and
the shard_map multi-device engine -- and reports the wall-time ratios,
plus a cell-by-cell argmin equivalence check so the speedup is never
bought with a wrong answer (the sharded engine must be *bit-identical* to
the single-device one). Compiled numbers include compilation (cold
start); a warm second pass is reported separately to show the
steady-state gap. The per-engine wall times + device count land in the
repo-root ``BENCH_sweep.json`` trajectory via ``benchmarks/run.py``.

On a CPU host, ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(set before jax initializes) exercises the real multi-device path; the
scaling-efficiency number is only meaningful when the forced devices map
to real cores.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import MAXWELL, codesign, enumerate_hw_space
from repro.core import sweep
from repro.core.workload import paper_workload

from .common import (
    SMOKE_HW_STRIDE,
    STENCIL_CLASSES as CLASSES,
    cache_json,
    emit,
    lm_enabled,
    refine_enabled,
    skey,
    smoke,
)


def _equivalent(res_np, res_jax) -> float:
    """Max relative gap between the engines' per-cell optima (the argmins
    may differ on exact ties; the achieved times must agree)."""
    finite = np.isfinite(res_np.cell_time)
    if not np.array_equal(finite, np.isfinite(res_jax.cell_time)):
        return float("inf")
    gap = np.abs(res_jax.cell_time[finite] - res_np.cell_time[finite])
    return float(np.max(gap / res_np.cell_time[finite]))


def _refine_stage(cls: str, res) -> dict:
    """Polish the reported best design with the batched coordinate descent
    (CodesignResult.refine) and land the speedup/quality delta in the
    artifact JSON -- the refine trajectory is now part of the tracked
    benchmark surface, not just a test fixture. The whole descent is one
    ``lax.while_loop`` dispatch (a single device->host sync), so refine_s
    here tracks the win over the old per-round blocking convergence check."""
    i, g0 = res.best(max_area=650.0)
    wt0 = float(res.weighted_time()[i])
    t0 = time.perf_counter()
    times, _ = res.refine(i)
    dt = time.perf_counter() - t0
    freqs = res.cell_freqs()
    wt1 = float(freqs @ times)
    flops = float(freqs @ res.cell_flops())
    g1 = flops / wt1 / 1.0e9
    improved = int(np.sum(times < res.cell_time[:, i]))
    rec = {
        "class": cls,
        "best_index": int(i),
        "refine_s": round(dt, 4),
        "cells_improved": improved,
        "cells": int(len(times)),
        "weighted_time_lattice_s": wt0,
        "weighted_time_refined_s": wt1,
        "gflops_lattice": g0,
        "gflops_refined": g1,
        "quality_delta_pct": 100.0 * (g1 / g0 - 1.0) if g0 else 0.0,
    }
    cache_json(skey(f"sweep_refine_{cls}"), lambda: rec, force=True)
    emit(
        f"sweep_refine_{cls}", dt * 1e6,
        f"best design {i}: {improved}/{len(times)} cells improved, "
        f"{g0:.1f} -> {g1:.1f} GFLOP/s ({rec['quality_delta_pct']:+.2f}%) "
        f"in {dt:.2f}s",
    )
    # wt0 is the jax engine's float32 sweep; wt1 is refine's float64
    # re-evaluation -- allow the cross-engine noise bound (same RTOL as the
    # equivalence tests), not a bitwise comparison
    assert wt1 <= wt0 * (1 + 1e-5), "refine regressed the lattice optimum"
    return rec


def _lm_stage() -> dict:
    """Time the LM cell family's eq.-(18) sweep (mesh factorizations x
    parallelism plans; see docs/lm_codesign.md) on both engines and check
    they agree -- feasibility bit-equal, achieved times within float32
    noise. The LM lattice is tiny next to a stencil sweep, so this stage
    reports the sweep *and* the warm re-dispatch cost, smoke or not; smoke
    shrinks the models (``cfg.reduced()``) and the chip budget so the
    ``jax.eval_shape`` parameter counting stays CI-cheap."""
    from repro.configs import get_arch
    from repro.core.lmcells import lm_codesign, lm_workload

    names = ["llama3-8b", "mixtral-8x22b"]
    if smoke():
        archs = [get_arch(n).reduced() for n in names]
        max_chips = 64
    else:
        archs = list(names)
        max_chips = 512
    wl = lm_workload(archs=archs, name="bench-lm")

    t0 = time.perf_counter()
    res_np = lm_codesign(wl, max_chips=max_chips, engine="numpy")
    t_np = time.perf_counter() - t0

    rec = {
        "models": names,
        "smoke_reduced": smoke(),
        "cells": len(wl.cells),
        "hw_points": len(res_np.hw),
        "max_chips": max_chips,
        "numpy_s": round(t_np, 4),
    }
    derived = f"{len(wl.cells)} cells x {len(res_np.hw)} meshes: numpy {t_np:.2f}s"
    if sweep.HAVE_JAX:
        t0 = time.perf_counter()
        res_jax = lm_codesign(wl, max_chips=max_chips, engine="jax")
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        lm_codesign(wl, max_chips=max_chips, engine="jax")
        t_warm = time.perf_counter() - t0

        finite = np.isfinite(res_np.cell_time)
        assert np.array_equal(finite, np.isfinite(res_jax.cell_time)), (
            "LM engines disagree on feasibility"
        )
        gap = float(np.max(np.abs(
            res_jax.cell_time[finite] / res_np.cell_time[finite] - 1.0
        ))) if finite.any() else 0.0
        # jax runs the grid in float32; the oracle is float64 -- the tests
        # (tests/test_lmcells.py) pin the tie-aware argmin contract, the
        # bench just refuses to report a speedup bought with a wrong answer
        assert gap < 1e-4, f"LM engines diverged: {gap}"
        rec.update(
            jax_cold_s=round(t_cold, 4), jax_warm_s=round(t_warm, 4),
            max_rel_gap=gap,
        )
        derived += (
            f", jax cold {t_cold:.2f}s / warm {t_warm:.3f}s; "
            f"max rel gap {gap:.1e}"
        )
    else:
        derived += " (jax not installed; oracle only)"
    cache_json(skey("sweep_lm"), lambda: rec, force=True)
    emit("sweep_lm", t_np * 1e6, derived)
    return rec


def run() -> dict | None:
    """Run the engine comparison; returns the trajectory record that
    ``benchmarks/run.py`` appends to the repo-root ``BENCH_sweep.json``."""
    if not sweep.HAVE_JAX:
        emit("sweep_engine", 0.0, "skipped (jax not installed)")
        return None
    n_dev = sweep.device_count()
    # the 1-device mesh is the degenerate case (same program as "jax", and
    # tests/test_sweep_sharded.py already pins its bit-identity): timing it
    # would double the compiled-engine cost of the single-device smoke lane
    # for no signal. The CI sharded lane forces 8 host devices.
    run_sharded = n_dev > 1 and sweep.HAVE_SHARD_MAP
    hw = enumerate_hw_space(MAXWELL, max_area=650.0)
    if smoke():
        hw = hw.downsample(SMOKE_HW_STRIDE)
    totals = {"numpy": 0.0, "jax_cold": 0.0, "jax_warm": 0.0,
              "sharded_cold": 0.0, "sharded_warm": 0.0}
    classes: dict = {}
    for cls, names in CLASSES.items():
        wl = paper_workload(names, name=f"sweep-{cls}")
        sweep.clear_caches()  # honest cold start: compile time is charged

        t0 = time.perf_counter()
        res_jax = codesign(wl, hw=hw, engine="jax")
        t_cold = time.perf_counter() - t0

        t0 = time.perf_counter()
        codesign(wl, hw=hw, engine="jax")
        t_warm = time.perf_counter() - t0

        if run_sharded:
            t0 = time.perf_counter()
            res_sh = codesign(wl, hw=hw, engine="sharded")
            t_sh_cold = time.perf_counter() - t0

            t0 = time.perf_counter()
            codesign(wl, hw=hw, engine="sharded")
            t_sh_warm = time.perf_counter() - t0

        t0 = time.perf_counter()
        res_np = codesign(wl, hw=hw, engine="numpy")
        t_np = time.perf_counter() - t0

        gap = _equivalent(res_np, res_jax)
        assert gap < 1e-5, f"engines diverged on {cls}: {gap}"
        totals["numpy"] += t_np
        totals["jax_cold"] += t_cold
        totals["jax_warm"] += t_warm
        classes[cls] = {
            "cells": len(wl.cells), "hw": len(hw), "numpy_s": round(t_np, 4),
            "jax_cold_s": round(t_cold, 4), "jax_warm_s": round(t_warm, 4),
        }
        emit(
            f"sweep_{cls}", t_cold * 1e6,
            f"{len(wl.cells)} cells x {len(hw)} hw: numpy {t_np:.1f}s, "
            f"jax cold {t_cold:.1f}s ({t_np/t_cold:.1f}x) / warm {t_warm:.1f}s "
            f"({t_np/t_warm:.1f}x); max argmin gap {gap:.1e}",
        )
        if run_sharded:
            # the sharded engine runs the same compiled body per shard: any
            # difference from the single-device engine is a sharding bug,
            # so the bar is bit-identity, not a tolerance.
            assert np.array_equal(res_sh.cell_time, res_jax.cell_time) and (
                np.array_equal(res_sh.cell_tile_idx, res_jax.cell_tile_idx)
            ), f"sharded engine not bit-identical on {cls}"
            totals["sharded_cold"] += t_sh_cold
            totals["sharded_warm"] += t_sh_warm
            classes[cls]["sharded_cold_s"] = round(t_sh_cold, 4)
            classes[cls]["sharded_warm_s"] = round(t_sh_warm, 4)
            emit(
                f"sweep_sharded_{cls}", t_sh_cold * 1e6,
                f"{n_dev} device(s): cold {t_sh_cold:.1f}s / warm "
                f"{t_sh_warm:.1f}s ({t_warm/t_sh_warm:.2f}x vs single-device "
                f"warm); bit-identical",
            )
        if refine_enabled():
            r = _refine_stage(cls, res_jax)
            classes[cls]["refine_s"] = r["refine_s"]
            classes[cls]["refine_quality_delta_pct"] = round(
                r["quality_delta_pct"], 4
            )
    emit(
        "sweep_total", totals["jax_cold"] * 1e6,
        f"numpy {totals['numpy']:.1f}s vs jax {totals['jax_cold']:.1f}s cold "
        f"incl. compile -> {totals['numpy']/totals['jax_cold']:.1f}x",
    )
    if not run_sharded:
        for k in ("sharded_cold", "sharded_warm"):
            del totals[k]  # never timed; zeros would read as measurements
    rec = {
        "suite": "sweep",
        "smoke": smoke(),
        "device_count": n_dev,
        "hw_points": len(hw),
        "classes": classes,
        "engines_total_s": {k: round(v, 4) for k, v in totals.items()},
    }
    if lm_enabled():
        rec["lm"] = _lm_stage()
    if run_sharded:
        # scaling efficiency: warm speedup over the single-device engine
        # per mesh device. 1.0 = perfect linear scaling; meaningful only
        # when the devices are real (forced host devices share cores).
        speedup = totals["jax_warm"] / max(totals["sharded_warm"], 1e-9)
        efficiency = speedup / max(n_dev, 1)
        emit(
            "sweep_sharded_total", totals["sharded_cold"] * 1e6,
            f"{n_dev} device(s): warm {totals['sharded_warm']:.1f}s vs "
            f"single-device warm {totals['jax_warm']:.1f}s -> {speedup:.2f}x "
            f"({100 * efficiency:.0f}% scaling efficiency)",
        )
        rec["sharded_speedup_vs_jax_warm"] = round(speedup, 4)
        rec["scaling_efficiency"] = round(efficiency, 4)
    else:
        why = (
            "this jax lacks shard_map"
            if not sweep.HAVE_SHARD_MAP
            else f"{n_dev} device(s); needs a multi-device mesh"
        )
        emit(
            "sweep_sharded_total", 0.0,
            f"skipped ({why} -- see the CI sharded-smoke lane)",
        )
    return rec
