"""Versioned HTTP/JSON wire codec for the codesign query service.

This module is the single source of truth for how a
:class:`repro.service.query.QueryRequest` and its
:class:`~repro.service.query.QueryResponse` cross a process boundary.
Everything else (the gateway's HTTP handler, the thin client, the CLI's
``--url`` mode, the CI smoke lane) encodes and decodes through these four
functions, so the in-process objects and the wire can never drift apart:

* :func:`encode_request` / :func:`decode_request` -- request envelope
  (``{"v", "artifact", "route", "request"}``);
* :func:`encode_response` / :func:`decode_response` -- response envelope
  (``{"v", "ok", "response"}`` on success, ``{"v", "ok", "error"}`` on
  failure);
* :func:`encode_error` -- structured error payloads (``code`` +
  ``message``), never tracebacks.

Design rules (documented for clients in ``docs/serving.md``):

* **Canonical bytes.** Encoders emit ``sort_keys=True`` +
  ``separators=(",", ":")`` JSON, and Python's ``repr``-based float
  serialization round-trips every float64 exactly. Encoding is therefore
  deterministic: the same ``QueryResponse`` always produces the same
  bytes, which is what lets tests (and the CI smoke lane) assert that an
  HTTP answer is *byte-identical* to the in-process answer.
* **Non-finite floats.** Strict JSON has no ``inf``/``nan``, but the
  service's contract does (``best_gflops = -inf`` means "no feasible
  design"). Non-finite floats are encoded as a tagged object
  ``{"$f": "inf" | "-inf" | "nan"}`` and decoded back to the exact float.
* **Versioning.** Every envelope carries ``"v": WIRE_VERSION``. A server
  rejects requests whose major version it does not speak
  (``unsupported_version``); a *client* decoding a response tolerates
  unknown **response** fields (servers may add fields within a version),
  while a *server* rejects unknown **request** fields (a typo'd field
  silently ignored would answer the wrong question).
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, Mapping, Optional, Tuple

import numpy as np

from .query import QueryRequest, QueryResponse

__all__ = [
    "WIRE_VERSION",
    "WireError",
    "RemoteError",
    "encode_request",
    "decode_request",
    "encode_response",
    "decode_response",
    "encode_error",
]

#: Wire (envelope) version. Bump only for incompatible envelope changes;
#: additive response fields do NOT bump it (clients ignore unknowns).
WIRE_VERSION = 1

#: request fields a v1 server accepts, mirroring QueryRequest exactly.
_REQUEST_FIELDS = frozenset(f.name for f in dataclasses.fields(QueryRequest))


class WireError(ValueError):
    """A request that cannot be decoded (malformed JSON, wrong types,
    unknown fields, unsupported version). Maps to HTTP 400."""

    def __init__(self, message: str, code: str = "bad_request"):
        super().__init__(message)
        self.code = code


class RemoteError(RuntimeError):
    """A structured error answer from a gateway (the client-side mirror of
    :func:`encode_error`); carries the server's ``code`` and HTTP status."""

    def __init__(self, code: str, message: str, http_status: int = 0):
        super().__init__(f"[{code}] {message}")
        self.code = code
        self.message = message
        self.http_status = http_status


# ---------------------------------------------------------------------------
# float / array tagging
# ---------------------------------------------------------------------------
_NONFINITE = {"inf": math.inf, "-inf": -math.inf}


def _jsonify(obj: Any) -> Any:
    """Recursively convert to strict-JSON-safe values: numpy scalars/arrays
    to native, non-finite floats to ``{"$f": ...}`` tags."""
    if isinstance(obj, (np.floating, np.integer)):
        obj = obj.item()
    if isinstance(obj, float):
        if math.isfinite(obj):
            return obj
        if math.isnan(obj):
            return {"$f": "nan"}
        return {"$f": "inf" if obj > 0 else "-inf"}
    if isinstance(obj, np.ndarray):
        return [_jsonify(x) for x in obj.tolist()]
    if isinstance(obj, dict):
        return {str(k): _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(x) for x in obj]
    return obj


def _unjsonify(obj: Any) -> Any:
    """Invert :func:`_jsonify` (tags back to floats)."""
    if isinstance(obj, dict):
        if set(obj) == {"$f"}:
            tag = obj["$f"]
            if tag == "nan":
                return math.nan
            if tag in _NONFINITE:
                return _NONFINITE[tag]
            raise WireError(f"unknown non-finite float tag {tag!r}")
        return {k: _unjsonify(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_unjsonify(x) for x in obj]
    return obj


def _dumps(obj: Any) -> bytes:
    return json.dumps(
        _jsonify(obj), sort_keys=True, separators=(",", ":"), allow_nan=False
    ).encode()


def _loads(data: bytes) -> Any:
    try:
        return json.loads(data.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireError(f"malformed JSON: {e}") from e


def _check_version(obj: Any, what: str) -> None:
    if not isinstance(obj, dict):
        raise WireError(f"{what} must be a JSON object, got {type(obj).__name__}")
    v = obj.get("v")
    if v != WIRE_VERSION:
        raise WireError(
            f"unsupported wire version {v!r} (this endpoint speaks v{WIRE_VERSION})",
            code="unsupported_version",
        )


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------
def encode_request(
    request: QueryRequest,
    artifact: Optional[str] = None,
    route: Optional[Mapping[str, Any]] = None,
) -> bytes:
    """Serialize one query. ``artifact`` pins a content-address key;
    ``route`` is a routing selector the gateway resolves (e.g.
    ``{"gpu": "titanx"}``); both ``None`` is valid on a one-artifact
    gateway."""
    body: Dict[str, Any] = {
        "v": WIRE_VERSION,
        "request": dataclasses.asdict(request),
    }
    if artifact is not None:
        body["artifact"] = str(artifact)
    if route:
        body["route"] = dict(route)
    return _dumps(body)


def decode_request(data: bytes) -> Tuple[QueryRequest, Optional[str], Optional[dict]]:
    """Bytes -> ``(QueryRequest, artifact_key, route)``.

    Raises :class:`WireError` on malformed JSON, a version this codec does
    not speak, non-object envelopes, or unknown request fields (strict on
    purpose: a silently dropped field would answer a different question
    than the client asked).
    """
    obj = _loads(data)
    _check_version(obj, "request envelope")
    unknown = set(obj) - {"v", "artifact", "route", "request"}
    if unknown:
        raise WireError(f"unknown envelope fields {sorted(unknown)}")
    artifact = obj.get("artifact")
    if artifact is not None and not isinstance(artifact, str):
        raise WireError("'artifact' must be a string key")
    route = obj.get("route")
    if route is not None and not isinstance(route, dict):
        raise WireError("'route' must be an object of selector: value pairs")
    req = obj.get("request")
    if not isinstance(req, dict):
        raise WireError("'request' must be an object (the QueryRequest fields)")
    req = _unjsonify(req)
    unknown = set(req) - _REQUEST_FIELDS
    if unknown:
        raise WireError(
            f"unknown request fields {sorted(unknown)} "
            f"(v{WIRE_VERSION} accepts {sorted(_REQUEST_FIELDS)})"
        )
    try:
        # coerce scalars so garbage fails HERE (bad_request) rather than
        # deep inside the engine -- and so a JSON "450" behaves like 450
        # instead of poisoning later comparisons with a str
        for name, conv in (("max_area", float), ("min_area", float),
                           ("top_k", int)):
            if name in req:
                req[name] = conv(req[name])
        for name in ("pareto", "use_cache"):
            if name in req and not isinstance(req[name], bool):
                raise WireError(f"{name!r} must be a boolean")
        request = QueryRequest(**req)
        if request.freqs is not None and not isinstance(request.freqs, dict):
            raise WireError("'freqs' must be an object of stencil: weight")
        if request.fix is not None and not isinstance(request.fix, dict):
            raise WireError("'fix' must be an object of param: value")
    except WireError:
        raise
    except (TypeError, ValueError) as e:
        raise WireError(f"bad request field: {e}") from e
    return request, artifact, route


# ---------------------------------------------------------------------------
# responses / errors
# ---------------------------------------------------------------------------
def encode_response(response: QueryResponse) -> bytes:
    """Serialize a success answer. Deterministic (canonical JSON), so two
    equal responses always encode to identical bytes -- the property the
    gateway's byte-identity acceptance test leans on."""
    r: Dict[str, Any] = {
        "artifact_key": response.artifact_key,
        "best_index": int(response.best_index),
        "best_gflops": float(response.best_gflops),
        "best_weighted_time": float(response.best_weighted_time),
        "best_point": dict(response.best_point),
        "top_k": [dict(t) for t in response.top_k],
        "cached": bool(response.cached),
        "batch_size": int(response.batch_size),
    }
    if response.pareto_indices is not None:
        r["pareto_indices"] = [int(i) for i in np.asarray(response.pareto_indices)]
    if response.baseline_best_index is not None:
        r["baseline_best_index"] = int(response.baseline_best_index)
        r["baseline_best_gflops"] = float(response.baseline_best_gflops)
    return _dumps({"v": WIRE_VERSION, "ok": True, "response": r})


def decode_response(data: bytes, http_status: int = 0) -> QueryResponse:
    """Bytes -> :class:`QueryResponse`. A structured error envelope raises
    :class:`RemoteError`; unknown *response* fields are ignored (additive
    server evolution within a wire version)."""
    obj = _loads(data)
    _check_version(obj, "response envelope")
    if not obj.get("ok"):
        err = obj.get("error") or {}
        raise RemoteError(
            str(err.get("code", "unknown")),
            str(err.get("message", "(no message)")),
            http_status,
        )
    r = obj.get("response")
    if not isinstance(r, dict):
        raise WireError("'response' must be an object")
    r = _unjsonify(r)
    pareto = r.get("pareto_indices")
    return QueryResponse(
        artifact_key=r["artifact_key"],
        best_index=int(r["best_index"]),
        best_gflops=float(r["best_gflops"]),
        best_weighted_time=float(r["best_weighted_time"]),
        best_point=r["best_point"],
        top_k=list(r["top_k"]),
        pareto_indices=None if pareto is None else np.asarray(pareto, np.int64),
        baseline_best_index=r.get("baseline_best_index"),
        baseline_best_gflops=r.get("baseline_best_gflops"),
        cached=bool(r.get("cached", False)),
        batch_size=int(r.get("batch_size", 1)),
    )


def encode_error(code: str, message: str) -> bytes:
    """Structured failure payload (the only thing a gateway ever sends on
    error -- clients never parse tracebacks)."""
    return _dumps(
        {"v": WIRE_VERSION, "ok": False,
         "error": {"code": str(code), "message": str(message)}}
    )
