#!/usr/bin/env python
"""CI chaos lane for the gateway's resilience layer: real processes, real
sockets, real injected faults.

Each scenario starts ``python -m repro.service.cli serve`` as a child
armed via the ``REPRO_FAULTS`` env var (:mod:`repro.service.faults`) and
asserts three things: the failure is **structured** (documented wire code
+ HTTP status, never a hung connection or a traceback), responses are
**never corrupted** (success bytes stay byte-identical to an in-process
oracle over the same artifact), and the stack **recovers** once the
fault clears (faults are count-limited, so the harness can outlive them).

1. slow store + deadline: ``store.open`` latency makes a 100ms-budget
   request answer 504 ``deadline_exceeded``; the next (fault-free,
   budget-free) request is byte-identical to the oracle;
2. failing store + circuit breaker: two injected ``store.open`` errors
   answer 500 ``internal`` and open the breaker (threshold 2); the next
   request fails fast as 503 ``circuit_open`` + Retry-After WITHOUT
   touching the store; after the cooldown a half-open probe recovers and
   answers byte-identically;
3. dropped sockets + client retries: the handler abandons two
   connections mid-request; the stock ``GatewayClient`` retry policy
   resends (connection reset = provably-unexecuted) and the caller sees
   one transparent, byte-identical success;
4. held build lock: with another process owning the build flock and
   ``REPRO_LOCK_TIMEOUT_S=1``, ``cli build`` exits 2 with a one-line
   ``build_lock_timeout`` error -- no traceback, no hang;
5. rate limiting: ``serve --client-rate-limit`` answers 429
   ``rate_limited`` + Retry-After once the bucket drains, and a client
   honoring the hint succeeds on retry.

Exit 0 and print PASS only if every check holds.

Usage: python scripts/chaos_smoke.py [--store DIR] [--downsample N]
"""

from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import tempfile
import time
import urllib.error
import urllib.request

# runnable with or without `pip install -e .` (CI installs; dev may not)
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.service import (  # noqa: E402
    ArtifactStore,
    CodesignServer,
    GatewayClient,
    RetryPolicy,
    wire,
)
from repro.service.query import QueryRequest  # noqa: E402

try:
    import fcntl  # noqa: E402
except ImportError:  # pragma: no cover - non-POSIX
    fcntl = None

CLI = [sys.executable, "-m", "repro.service.cli"]
GPU = "gtx980"


def _env(**extra: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["PYTHONUNBUFFERED"] = "1"
    env.update(extra)
    return env


def check(ok: bool, what: str) -> None:
    print(f"  {'ok' if ok else 'FAIL'}: {what}")
    if not ok:
        raise SystemExit(f"chaos smoke failed at: {what}")


class Serve:
    """One `cli serve` child with faults/flags; context-managed teardown."""

    def __init__(self, store_root: str, *flags: str, faults_spec=None):
        env = _env()
        if faults_spec:
            env["REPRO_FAULTS"] = json.dumps(faults_spec)
        self.proc = subprocess.Popen(
            CLI + ["serve", "--store", store_root, "--port", "0", *flags],
            stdout=subprocess.PIPE, text=True, env=env,
        )
        self.url = None
        for line in self.proc.stdout:  # the bound port is printed last
            m = re.search(r"serving on (http://\S+)", line)
            if m:
                self.url = m.group(1)
                break
        check(self.url is not None, "serve printed its bound address")

    def __enter__(self) -> "Serve":
        return self

    def __exit__(self, *exc) -> None:
        self.proc.terminate()
        self.proc.wait(timeout=30)


def post(url: str, body: bytes, path: str = "/v1/query", headers=None):
    """(status, headers, body) for one POST; HTTP errors return, not raise."""
    req = urllib.request.Request(
        url + path, data=body, method="POST",
        headers={"Content-Type": "application/json", **(headers or {})},
    )
    try:
        with urllib.request.urlopen(req, timeout=30) as r:
            return r.status, dict(r.headers), r.read()
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), e.read()


def assert_coded(status, body, code: str, what: str) -> None:
    payload = json.loads(body)
    check(
        status == wire.ERROR_HTTP_STATUS[code]
        and payload.get("ok") is False
        and payload["error"]["code"] == code
        and bool(payload["error"]["message"]),
        what,
    )


def scrape(url: str) -> dict:
    with urllib.request.urlopen(url + "/v1/metrics?format=json", timeout=30) as r:
        return json.loads(r.read())


def total(snap: dict, name: str) -> float:
    metric = snap.get(name)
    if not metric:
        return 0.0
    return sum(s["value"] for s in metric["samples"])


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--store", default=None, help="store dir (default: temp)")
    ap.add_argument("--downsample", type=int, default=48,
                    help="hw-space thinning for the tiny build")
    args = ap.parse_args()
    store_root = args.store or tempfile.mkdtemp(prefix="chaos-smoke-")

    print(f"[1/6] building one artifact under {store_root}")
    subprocess.run(
        CLI + ["build", "--store", store_root, "--gpu", GPU,
               "--engine", "numpy", "--downsample", str(args.downsample)],
        check=True, env=_env(), timeout=600,
    )
    store = ArtifactStore(store_root)
    key = store.keys()[0]
    oracle = CodesignServer.from_artifact(store, store.get(key), batch_window=0.0)
    req = QueryRequest(freqs={"heat2d": 2.0, "jacobi2d": 1.0},
                       max_area=500.0, top_k=3, use_cache=False)
    want = wire.encode_response(oracle.query(req))
    body = wire.encode_request(req, artifact=key)

    print("[2/6] slow store + deadline -> 504, then clean recovery")
    with Serve(store_root,
               faults_spec={"store.open": {"latency_s": 0.5, "count": 1}}) as s:
        status, _, raw = post(
            s.url, body, headers={"X-Repro-Deadline-Ms": "100"}
        )
        assert_coded(status, raw, "deadline_exceeded",
                     "100ms budget vs 500ms store latency -> 504 deadline_exceeded")
        snap = scrape(s.url)
        check(total(snap, "repro_resilience_deadline_exceeded_total") >= 1,
              "deadline metric counted the hit")
        check(total(snap, "repro_faults_fired_total") == 1,
              "exactly one injected fault fired")
        status, _, raw = post(s.url, body)
        check(status == 200 and raw == want,
              "fault cleared: answer byte-identical to the in-process oracle")

    print("[3/6] failing store -> breaker opens -> fail-fast -> probe recovers")
    with Serve(store_root, "--breaker-threshold", "2",
               "--breaker-cooldown", "1",
               faults_spec={"store.open":
                            {"error": "OSError:injected disk failure",
                             "count": 2}}) as s:
        for i in (1, 2):
            status, _, raw = post(s.url, body)
            assert_coded(status, raw, "internal",
                         f"raw store failure {i} -> 500 internal")
        status, headers, raw = post(s.url, body)
        assert_coded(status, raw, "circuit_open",
                     "threshold reached -> 503 circuit_open (fail-fast)")
        check(int(headers.get("Retry-After", 0)) >= 1,
              "circuit_open carries Retry-After")
        snap = scrape(s.url)
        check(total(snap, "repro_resilience_breaker_transitions_total") >= 1,
              "breaker transition metric recorded")
        time.sleep(1.2)  # cooldown: the next request is the half-open probe
        status, _, raw = post(s.url, body)
        check(status == 200 and raw == want,
              "half-open probe recovers, byte-identical answer")

    print("[4/6] dropped sockets -> client retry policy recovers transparently")
    with Serve(store_root,
               faults_spec={"gateway.drop_socket": {"count": 2}}) as s:
        client = GatewayClient(
            s.url, retry=RetryPolicy(max_retries=3, base_s=0.05)
        )
        raw = client.query_bytes(req, artifact=key)
        check(raw == want,
              "two dropped connections -> retried, byte-identical answer")
        check(client.stats["retries"] == 2,
              f"client counted 2 retries (got {client.stats['retries']})")
        snap = scrape(s.url)
        check(total(snap, "repro_faults_fired_total") == 2,
              "both socket drops fired")

    print("[5/6] held build lock -> cli build exits 2 with build_lock_timeout")
    if fcntl is None:
        print("  skip: no fcntl on this platform")
    else:
        from repro.core.timemodel import GPUS_BY_NAME

        lock_root = tempfile.mkdtemp(prefix="chaos-lock-")
        # the key `cli build` will want, computed without building (the
        # spec is content-addressed: same params -> same key)
        probe = CodesignServer(
            ArtifactStore(lock_root), gpu=GPUS_BY_NAME[GPU],
            downsample=args.downsample, engine="numpy", batch_window=0.0,
        )
        lock_path = os.path.join(lock_root, f".lock-{probe.key}")
        fd = os.open(lock_path, os.O_CREAT | os.O_RDWR, 0o644)
        fcntl.flock(fd, fcntl.LOCK_EX)
        try:
            r = subprocess.run(
                CLI + ["build", "--store", lock_root, "--gpu", GPU,
                       "--engine", "numpy",
                       "--downsample", str(args.downsample)],
                capture_output=True, text=True, timeout=120,
                env=_env(REPRO_LOCK_TIMEOUT_S="1"),
            )
            check(r.returncode == 2, "held lock -> exit 2")
            check("build_lock_timeout" in r.stderr
                  and "Traceback" not in r.stderr,
                  "one-line build_lock_timeout error, no traceback")
        finally:
            fcntl.flock(fd, fcntl.LOCK_UN)
            os.close(fd)

    print("[6/6] rate limit -> 429 + Retry-After; honoring it succeeds")
    with Serve(store_root, "--client-rate-limit", "1") as s:
        status, _, _ = post(s.url, body)
        check(status == 200, "first request rides the burst token")
        status, headers, raw = post(s.url, body)
        assert_coded(status, raw, "rate_limited",
                     "drained bucket -> 429 rate_limited")
        retry_after = int(headers.get("Retry-After", 0))
        check(retry_after >= 1, "429 carries Retry-After")
        client = GatewayClient(s.url, retry=RetryPolicy(max_retries=3))
        raw = client.query_bytes(req, artifact=key)
        check(raw == want and client.stats["retries"] >= 1,
              "client honored Retry-After and recovered byte-identically")
        snap = scrape(s.url)
        check(total(snap, "repro_resilience_rejections_total") >= 2,
              "rejection metrics counted both 429s")

    print("PASS: chaos smoke (deadlines + breaker + retries + lock timeout "
          "+ rate limit; zero corrupted responses)")


if __name__ == "__main__":
    main()
