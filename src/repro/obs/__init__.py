"""Dependency-free observability for the codesign stack.

Three small, stdlib-only modules, threaded through every hot path of the
sweep/serve/gateway system (see ``docs/observability.md``):

* :mod:`repro.obs.metrics` -- a process-wide registry of thread-safe
  counters, gauges, and fixed-bucket histograms with snapshot/reset
  semantics and two exporters (Prometheus text + canonical JSON). The
  gateway serves it at ``GET /v1/metrics``.
* :mod:`repro.obs.trace`   -- context-manager spans over the monotonic
  clock with parent/child nesting and a per-request trace id that rides
  the HTTP wire as an ``X-Repro-Trace`` header; a ``"trace": true``
  request envelope field returns the span tree in the response.
* :mod:`repro.obs.logging` -- structured JSON line logging with a
  verbosity knob (the CLI ``serve --log-level`` flag).

Design rule: observability is **additive, never on the answer path**.
Untraced ``/v1/query`` responses stay byte-identical whether or not
instrumentation is enabled, and ``REPRO_OBS_DISABLED=1`` turns every
metric into a no-op (asserted < 5% throughput delta in
``benchmarks/bench_service.py``).
"""

from .exemplar import ExemplarStore  # noqa: F401
from .logging import configure_logging, get_logger  # noqa: F401
from .slo import (  # noqa: F401
    DEFAULT_OBJECTIVES,
    SLOObjective,
    SLOTracker,
    bucket_quantile,
)
from .metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    Registry,
    get_registry,
    set_disabled,
)
from .trace import (  # noqa: F401
    TRACE_HEADER,
    Span,
    current_span,
    current_trace_id,
    new_trace_id,
    span,
    trace,
)
