"""mamba2-780m [ssm]: attention-free, SSD (state-space duality).
[arXiv:2405.21060; unverified]"""

from .base import ArchConfig, SSMConfig, register

CONFIG = register(
    ArchConfig(
        name="mamba2-780m",
        family="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=0,  # attention-free
        n_kv_heads=0,
        d_ff=0,  # no separate MLP: the mamba block is the whole layer
        vocab=50280,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        tie_embeddings=True,
        source="arXiv:2405.21060; unverified",
    )
)
