"""Paper §V.A: the cache-removal comparison -- codesigned cache-less designs
vs stock GPUs at (a) equal total area and (b) equal cache-less area."""

from __future__ import annotations

import time

from repro.core import GTX980, MAXWELL, TITAN_X, cacheless, codesign, enumerate_hw_space
from repro.core.codesign import evaluate_fixed_hw
from repro.core.workload import paper_workload

from .common import SMOKE_HW_STRIDE, STENCIL_CLASSES, cache_json, emit, skey, smoke

#: §V.A reported numbers for the derived column
PAPER = {
    ("2d", "gtx980"): 9.34, ("2d", "titanx"): 28.44,
    ("3d", "gtx980"): 9.22, ("3d", "titanx"): 33.15,
}


def _solve() -> dict:
    out = {}
    hw = enumerate_hw_space(MAXWELL, max_area=650.0)
    if smoke():
        hw = hw.downsample(SMOKE_HW_STRIDE)
    for cls, names in STENCIL_CLASSES.items():
        wl = paper_workload(names)
        t0 = time.perf_counter()
        res = codesign(wl, hw=hw)
        dt = time.perf_counter() - t0
        for gpu, point in (("gtx980", GTX980), ("titanx", TITAN_X)):
            _, stock = evaluate_fixed_hw(wl, point)
            a_less = MAXWELL.area_point(cacheless(point))
            _, best_less = res.best(max_area=a_less)
            out[f"{cls}_{gpu}"] = {
                "stock_gflops": stock,
                "cacheless_area": a_less,
                "best_at_cacheless_area": best_less,
                "improvement_pct": 100 * (best_less / stock - 1),
                "solve_s": dt,
            }
    return out


def run() -> None:
    table = cache_json(skey("cache_removal"), _solve)
    for key, r in table.items():
        cls, gpu = key.split("_")
        emit(
            f"cacheless_{key}", r["solve_s"] * 1e6,
            f"stock {r['stock_gflops']:.0f} GFLOP/s vs codesigned "
            f"{r['best_at_cacheless_area']:.0f} @ cache-less area "
            f"{r['cacheless_area']:.0f} mm^2 (+{r['improvement_pct']:.1f}%; "
            f"paper: +{PAPER[(cls, gpu)]:.2f}%)",
        )
