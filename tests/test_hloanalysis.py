"""Validate the scan-aware HLO analyzer against analytic FLOP counts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hloanalysis import analyze_hlo


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    text = _compiled_text(lambda x, y: x @ y, a, b)
    t = analyze_hlo(text)
    assert t.dot_flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_scan_multiplies_by_trip_count():
    """A scan of N matmuls must count N x the single-matmul FLOPs."""
    n = 7
    w = jax.ShapeDtypeStruct((n, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)

    def f(ws, x0):
        def body(c, w):
            return jnp.tanh(c @ w), None

        out, _ = jax.lax.scan(body, x0, ws)
        return out

    t = analyze_hlo(_compiled_text(f, w, x))
    want = n * 2 * 8 * 32 * 32
    assert t.dot_flops == pytest.approx(want, rel=0.05)
    assert n in t.while_trips


def test_nested_scans_multiply():
    """scan(M) of scan(N) of matmul -> M*N x flops."""
    m_out, n_in = 3, 5
    w = jax.ShapeDtypeStruct((m_out, n_in, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)

    def f(ws, x0):
        def outer(c, w_outer):
            def inner(ci, wi):
                return ci @ wi, None

            c2, _ = jax.lax.scan(inner, c, w_outer)
            return c2, None

        out, _ = jax.lax.scan(outer, x0, ws)
        return out

    t = analyze_hlo(_compiled_text(f, w, x))
    want = m_out * n_in * 2 * 4 * 16 * 16
    assert t.dot_flops == pytest.approx(want, rel=0.05)


def test_matches_cost_analysis_without_loops():
    """On loop-free programs our dot accounting ~= XLA cost analysis."""
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)

    def f(x, y):
        return jax.nn.relu(x @ y) @ y.T

    compiled = jax.jit(f).lower(a, b).compile()
    t = analyze_hlo(compiled.as_text())
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    # cost_analysis counts elementwise flops too; dots dominate here
    assert t.dot_flops <= float(cost["flops"]) * 1.01
    assert t.dot_flops >= 0.9 * 2 * (128 * 256 * 512 + 128 * 512 * 256)
