"""Tail exemplars: span trees for the requests worth staring at.

Aggregates (histograms, burn rates) tell you the p99 regressed; they
cannot tell you *why*. This module keeps, per route, the full span trees
of exactly the requests an operator would ask for:

* the **slowest N** requests seen so far (a min-heap on duration: a new
  request evicts the fastest retained exemplar iff it is slower, so the
  retained set is deterministically the top-N regardless of thread
  interleaving), and
* the **most recent M error responses** (a ring: newest wins).

The gateway forces an internal trace for every request while capture is
enabled -- the client's response bytes are untouched (the trace tree is
only attached to the envelope when the client explicitly asked for it),
so untraced answers stay byte-identical. Exemplars are served at
``GET /v1/debug/exemplars`` and cross-referenced by the ``X-Repro-Trace``
response header: an operator who saw a slow request's trace id can pull
its tree minutes later.

Everything is bounded: memory is O(routes x (N + M) x tree size), and
``offer()`` is one lock acquisition plus at most one heap push-pop.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["ExemplarStore"]


class _RouteRing:
    __slots__ = ("slow", "errors")

    def __init__(self, max_errors: int):
        # min-heap of (duration_s, seq, entry): root = fastest retained
        self.slow: List[Tuple[float, int, Dict[str, Any]]] = []
        self.errors: deque = deque(maxlen=max_errors)


class ExemplarStore:
    """Bounded per-route retention of slow/error request exemplars."""

    def __init__(self, slow_n: int = 8, max_errors: int = 32, *,
                 clock=time.time):
        if slow_n < 1:
            raise ValueError(f"slow_n must be >= 1, got {slow_n}")
        if max_errors < 1:
            raise ValueError(f"max_errors must be >= 1, got {max_errors}")
        self._slow_n = slow_n
        self._max_errors = max_errors
        self._clock = clock
        self._mu = threading.Lock()
        self._routes: Dict[str, _RouteRing] = {}
        self._seq = itertools.count()

    def offer(
        self,
        route: str,
        trace_id: str,
        duration_s: float,
        status: int,
        code: Optional[str] = None,
        trace: Optional[Dict[str, Any]] = None,
    ) -> None:
        """Consider one finished request for retention. Cheap to decline:
        a fast, successful request on a full ring costs one comparison."""
        entry = {
            "route": route,
            "trace_id": trace_id,
            "dur_us": int(round(float(duration_s) * 1e6)),
            "status": int(status),
            "at": float(self._clock()),
        }
        if code is not None:
            entry["code"] = code
        if trace is not None:
            entry["trace"] = trace
        with self._mu:
            ring = self._routes.get(route)
            if ring is None:
                ring = self._routes.setdefault(route, _RouteRing(self._max_errors))
            if status >= 400:
                ring.errors.append(entry)
                return
            item = (float(duration_s), next(self._seq), entry)
            if len(ring.slow) < self._slow_n:
                heapq.heappush(ring.slow, item)
            elif item[0] > ring.slow[0][0]:
                heapq.heapreplace(ring.slow, item)

    def routes(self) -> List[str]:
        with self._mu:
            return sorted(self._routes)

    def snapshot(self, route: Optional[str] = None) -> Dict[str, Any]:
        """Deterministic snapshot: slow exemplars sorted slowest-first,
        errors in arrival order (oldest retained first). ``route=None``
        returns every route; an unknown route returns empty lists (the
        gateway validates route names before calling, so "no exemplars
        yet" and "unknown route" stay distinguishable)."""
        with self._mu:
            if route is not None:
                names = [route] if route in self._routes else []
            else:
                names = sorted(self._routes)
            picked = {
                n: (list(self._routes[n].slow), list(self._routes[n].errors))
                for n in names
            }
        out: Dict[str, Any] = {}
        for n, (slow, errors) in picked.items():
            out[n] = {
                "slow": [e for _, _, e in
                         sorted(slow, key=lambda it: (-it[0], it[1]))],
                "errors": list(errors),
            }
        if route is not None and route not in out:
            out[route] = {"slow": [], "errors": []}
        return {"slow_n": self._slow_n, "max_errors": self._max_errors,
                "routes": out}
