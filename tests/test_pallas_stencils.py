"""Tile-parameterized Pallas stencils vs the independent jnp oracle
(`kernels/ref.py`), across the eq.-18 tile lattice, in interpret mode on
CPU -- the tentpole equivalence property: every sweep-enumerable tile
configuration reproduces the reference evolution to f32 accumulation
accuracy (see :func:`assert_close` for the documented tolerance)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # soft dep: skips, not errors

from repro.kernels.pallas_stencils import (
    DEFAULT_TILES,
    TILE_NAMES,
    normalize_tiles,
    run_tiled,
    tile_footprint_cells,
)
from repro.kernels.ref import run_ref

NAMES_2D = ["jacobi2d", "heat2d", "laplacian2d", "gradient2d"]
NAMES_3D = ["heat3d", "laplacian3d"]

#: a slice of the sweep lattice (repro.core.solver.LATTICE_2D/3D values),
#: deliberately including tiles larger than the arrays, t_s1=1 strips, and
#: time tiles deeper than the run.
TILE_GRID_2D = [
    {"t_s1": 1, "t_s2": 32, "t_t": 2, "k": 1},
    {"t_s1": 4, "t_s2": 32, "t_t": 4, "k": 8},
    {"t_s1": 8, "t_s2": 64, "t_t": 2, "k": 2},
    {"t_s1": 16, "t_s2": 128, "t_t": 8, "k": 32},
    {"t_s1": 64, "t_s2": 1024, "t_t": 2, "k": 1},
]
TILE_GRID_3D = [
    {"t_s1": 1, "t_s2": 32, "t_t": 2, "k": 1, "t_s3": 1},
    {"t_s1": 4, "t_s2": 32, "t_t": 2, "k": 4, "t_s3": 2},
    {"t_s1": 8, "t_s2": 64, "t_t": 4, "k": 1, "t_s3": 8},
    {"t_s1": 32, "t_s2": 256, "t_t": 6, "k": 16, "t_s3": 4},
]

def _rand(shape, dtype=jnp.float32, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32).astype(dtype)


def assert_close(got, want, rtol=1e-4):
    """The documented equivalence tolerance: rtol=1e-4 elementwise plus an
    absolute slack of rtol x the field magnitude. Both sides accumulate in
    f32 but sum neighbor terms in different orders (tile-local vs whole
    array), and laplacian/gradient iterations amplify the field by orders
    of magnitude per step, so rounding differences compound relative to
    the *field* scale, not each cell's value. Single steps agree to
    ~1e-7; this bound holds across the tile grid and multi-step runs."""
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    scale = float(np.max(np.abs(want))) if want.size else 1.0
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol * max(1.0, scale))


def test_tile_names_match_sweep_order():
    """A packed sweep row (refine_points / decode_sw output) must be a
    valid tile config positionally -- the whole point of sharing names."""
    from repro.core.sweep import SW_NAMES

    assert TILE_NAMES == SW_NAMES


@pytest.mark.parametrize("name", NAMES_2D)
@pytest.mark.parametrize("tiles", TILE_GRID_2D)
def test_2d_tile_grid_matches_oracle(name, tiles):
    x = _rand((37, 53), seed=1)
    got = run_tiled(name, x, steps=5, tiles=tiles, interpret=True)
    want = run_ref(name, x, steps=5)
    assert_close(got, want)


@pytest.mark.parametrize("name", NAMES_3D)
@pytest.mark.parametrize("tiles", TILE_GRID_3D)
def test_3d_tile_grid_matches_oracle(name, tiles):
    x = _rand((11, 13, 17), seed=2)
    got = run_tiled(name, x, steps=4, tiles=tiles, interpret=True)
    want = run_ref(name, x, steps=4)
    assert_close(got, want)


@pytest.mark.parametrize("name", ["heat2d", "heat3d"])
def test_bf16_inputs_upcast_like_reference(name):
    shape = (24, 40) if name == "heat2d" else (10, 12, 14)
    x = _rand(shape, jnp.bfloat16, seed=3)
    got = run_tiled(name, x, steps=2, tiles={"t_s1": 8, "t_s2": 32, "t_t": 2})
    want = run_ref(name, x, steps=2)
    assert got.dtype == x.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_k_is_occupancy_only():
    """k (blocks co-resident per SM) schedules, never computes: results are
    identical across k."""
    x = _rand((29, 31), seed=4)
    outs = [
        np.asarray(run_tiled("jacobi2d", x, steps=3,
                             tiles={"t_s1": 8, "t_s2": 32, "t_t": 2, "k": k}))
        for k in (1, 8, 32)
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


def test_time_tile_depth_is_semantics_preserving():
    """Any t_t splits the same T steps into passes; values must agree."""
    x = _rand((25, 45), seed=5)
    want = run_ref("heat2d", x, steps=7)
    for t_t in (1, 2, 3, 7, 16):
        got = run_tiled("heat2d", x, steps=7, tiles={"t_s1": 8, "t_s2": 32, "t_t": t_t})
        assert_close(got, want)


def test_borders_are_dirichlet():
    x = _rand((18, 22), seed=6)
    y = run_tiled("laplacian2d", x, steps=3, tiles={"t_s1": 4, "t_s2": 32, "t_t": 2})
    np.testing.assert_array_equal(np.asarray(y[0]), np.asarray(x[0]))
    np.testing.assert_array_equal(np.asarray(y[-1]), np.asarray(x[-1]))
    np.testing.assert_array_equal(np.asarray(y[:, 0]), np.asarray(x[:, 0]))
    np.testing.assert_array_equal(np.asarray(y[:, -1]), np.asarray(x[:, -1]))


def test_normalize_tiles_contract():
    assert normalize_tiles(None) == tuple(DEFAULT_TILES[k] for k in TILE_NAMES)
    assert normalize_tiles({"t_s1": 2})[0] == 2
    with pytest.raises(ValueError, match="unknown tile parameter"):
        normalize_tiles({"t_sX": 2})
    with pytest.raises(ValueError, match=">= 1"):
        normalize_tiles({"t_t": 0})
    with pytest.raises(KeyError, match="unknown stencil"):
        run_tiled("nosuch", jnp.zeros((4, 4)), steps=1)
    with pytest.raises(ValueError, match="steps"):
        run_tiled("heat2d", jnp.zeros((4, 4)), steps=-1)


def test_zero_steps_is_identity():
    x = _rand((9, 9), seed=7)
    assert run_tiled("heat2d", x, steps=0) is x


def test_footprint_grows_with_time_tile():
    small = tile_footprint_cells(2, {"t_s1": 8, "t_s2": 32, "t_t": 2})
    deep = tile_footprint_cells(2, {"t_s1": 8, "t_s2": 32, "t_t": 8})
    assert deep > small
    assert tile_footprint_cells(3, {"t_s1": 8, "t_s2": 32, "t_t": 2}) > small


@settings(max_examples=12, deadline=None)
@given(
    name=st.sampled_from(NAMES_2D),
    rows=st.integers(3, 40),
    cols=st.integers(3, 60),
    t_s1=st.integers(1, 16),
    t_s2=st.sampled_from([32, 64]),
    t_t=st.integers(1, 5),
    steps=st.integers(1, 6),
)
def test_property_2d_any_tile_allclose(name, rows, cols, t_s1, t_s2, t_t, steps):
    x = _rand((rows, cols), seed=rows * cols)
    got = run_tiled(name, x, steps=steps,
                    tiles={"t_s1": t_s1, "t_s2": t_s2, "t_t": t_t})
    want = run_ref(name, x, steps=steps)
    assert_close(got, want)
