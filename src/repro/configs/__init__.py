"""Architecture + shape configuration registry (``--arch``, ``--shape``)."""

from .base import (  # noqa: F401
    ARCHS,
    SHAPES,
    ArchConfig,
    AttnConfig,
    MoEConfig,
    ShapeSpec,
    SSMConfig,
    get,
    get_arch,
    list_archs,
    register,
)
