"""Shared benchmark utilities: timing + CSV emission + artifact cache +
the --smoke contract (tiny problem sizes / downsampled hardware spaces so
the whole suite is CI-runnable in minutes)."""

from __future__ import annotations

import datetime
import json
import math
import os
import time
from typing import Callable, Dict

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: hardware-space downsampling stride used by suites in smoke mode.
SMOKE_HW_STRIDE = 8

#: the paper's two Fig.-3 workload classes -- single source of truth for
#: every suite that reproduces or cross-checks the Fig.-3 sweep.
STENCIL_CLASSES = {
    "2d": ["jacobi2d", "heat2d", "laplacian2d", "gradient2d"],
    "3d": ["heat3d", "laplacian3d"],
}


def smoke() -> bool:
    """True when running under ``benchmarks/run.py --smoke`` (env contract
    so suite modules stay import-order independent)."""
    return os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def refine_enabled() -> bool:
    """True when ``benchmarks/run.py --refine`` asked the sweep suite to run
    the batched coordinate-descent polish stage (same env contract)."""
    return os.environ.get("REPRO_BENCH_REFINE", "") == "1"


def lm_enabled() -> bool:
    """True when ``benchmarks/run.py --lm`` asked the sweep suite to time
    the LM cell family (mesh-factorization sweep) alongside the stencils."""
    return os.environ.get("REPRO_BENCH_LM", "") == "1"


def skey(key: str) -> str:
    """Artifact cache key, segregated per mode so smoke runs never poison
    (or read) the full-fidelity cache."""
    return key + ("_smoke" if smoke() else "")


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """(result, best microseconds per call)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return out, best


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The harness CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


#: key suffixes that declare a units contract for trajectory fields --
#: any field named ``*_s`` / ``*_qps`` / ``*_us`` (or any leaf under such
#: a field, e.g. ``engines_total_s``'s per-engine values) must be a
#: finite number, or the trajectory diff across PRs turns meaningless.
_NUMERIC_SUFFIXES = ("_s", "_qps", "_us")


def _leaves(value):
    if isinstance(value, dict):
        for v in value.values():
            yield from _leaves(v)
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _leaves(v)
    else:
        yield value


def validate_trajectory_entry(record: Dict) -> None:
    """Schema gate for trajectory entries (raises ``TypeError``/
    ``ValueError``): a dict carrying a non-empty ``"suite"`` string, with
    every units-suffixed field (see ``_NUMERIC_SUFFIXES``) holding finite
    numbers. A NaN/inf/None wall time means the suite recorded a
    measurement it never actually took -- fail the run, don't commit it."""
    if not isinstance(record, dict):
        raise TypeError(
            f"trajectory entry must be a dict, got {type(record).__name__}"
        )
    if not isinstance(record.get("suite"), str) or not record["suite"]:
        raise ValueError("trajectory entry must carry a non-empty 'suite' string")

    def _walk(obj: Dict, path: str) -> None:
        for k, v in obj.items():
            here = f"{path}.{k}" if path else str(k)
            if str(k).endswith(_NUMERIC_SUFFIXES):
                for leaf in _leaves(v):
                    if (
                        isinstance(leaf, bool)
                        or not isinstance(leaf, (int, float))
                        or not math.isfinite(leaf)
                    ):
                        raise ValueError(
                            f"trajectory field {here!r} must hold finite "
                            f"numbers, got {leaf!r}"
                        )
            elif isinstance(v, dict):
                _walk(v, here)

    _walk(record, "")


def append_trajectory(name: str, record: Dict) -> str:
    """Append a timestamped entry to the repo-root ``BENCH_<name>.json``
    perf trajectory (a JSON list, one entry per recorded run), so wall-time
    regressions are diffable across PRs. Returns the file path.

    Unlike :func:`cache_json` artifacts (scratch outputs under
    ``benchmarks/artifacts/``), the trajectory is a *committed* file: each
    PR's benchmark run extends it in place. Entries pass
    :func:`validate_trajectory_entry` before touching the file."""
    validate_trajectory_entry(record)
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    entries = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                entries = json.load(f)
        except (json.JSONDecodeError, OSError):
            entries = []  # corrupt trajectory: restart rather than crash
    if not isinstance(entries, list):
        entries = []
    entries.append(
        {"ts": datetime.datetime.now(datetime.timezone.utc).isoformat(), **record}
    )
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(entries, f, indent=1)
    os.replace(tmp, path)
    return path


def cache_json(key: str, compute: Callable[[], Dict], force: bool = False) -> Dict:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, key + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    out = compute()
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return out
