"""Paper §III.B-C: area-model calibration + Titan X validation."""

from __future__ import annotations

from repro.core.area import (
    GTX980,
    GTX980_DIE_MM2,
    MAXWELL,
    TITAN_X,
    TITAN_X_DIE_MM2,
    cacheless,
)

from .common import emit, timed


def run() -> None:
    (a980, us) = timed(MAXWELL.area_point, GTX980)
    emit(
        "area_gtx980_mm2", us,
        f"{a980:.1f} (published 398; err {100*(a980-GTX980_DIE_MM2)/GTX980_DIE_MM2:+.2f}%)",
    )
    atx, us = timed(MAXWELL.area_point, TITAN_X)
    emit(
        "area_titanx_mm2", us,
        f"{atx:.1f} (published 601; err {100*(atx-TITAN_X_DIE_MM2)/TITAN_X_DIE_MM2:+.2f}%; paper claims -1.96%)",
    )
    c980, us = timed(MAXWELL.area_point, cacheless(GTX980))
    emit("area_gtx980_cacheless_mm2", us, f"{c980:.1f} (paper: 237)")
    ctx, us = timed(MAXWELL.area_point, cacheless(TITAN_X))
    emit("area_titanx_cacheless_mm2", us, f"{ctx:.1f} (paper: 356)")
