"""Sharding rules: validity (divisibility), fallbacks, FSDP/ZeRO layering."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_arch
from repro.models.model import init_model
from repro.serve.kvcache import init_caches
from repro.sharding.partition import (
    batch_specs,
    cache_specs,
    opt_state_specs,
    param_specs,
)

get_arch("llama3-8b")
ALL = sorted(ARCHS)


def _fake_mesh(shape=(2, 2), axes=("data", "model")):
    """An abstract mesh (device objects only needed for NamedSharding)."""
    n = int(np.prod(shape))
    devs = np.array([jax.devices()[0]] * n).reshape(shape)

    class _M:
        axis_names = axes
        devices = devs

    return _M()


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _check_divisible(tree_specs, tree_shapes, mesh):
    sizes = _axis_sizes(mesh)
    leaves_spec = jax.tree.leaves(tree_specs, is_leaf=lambda x: isinstance(x, P))
    leaves_shape = jax.tree.leaves(tree_shapes)
    assert len(leaves_spec) == len(leaves_shape)
    for spec, leaf in zip(leaves_spec, leaves_shape):
        shape = leaf.shape
        for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = int(np.prod([sizes.get(a, 1) for a in axes]))
            assert dim % total == 0, (spec, shape)
            # no duplicate axis use within one spec
        used = [a for e in spec if e is not None for a in (e if isinstance(e, tuple) else (e,))]
        assert len(used) == len(set(used)), spec


@pytest.mark.parametrize("arch", ALL)
@pytest.mark.parametrize("fsdp", [False, True])
def test_full_config_param_specs_are_valid(arch, fsdp):
    """FULL configs x production-mesh axis sizes: every spec divides."""
    cfg = ARCHS[arch]
    mesh = _fake_mesh((16, 16), ("data", "model"))
    shapes = jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(cfg, shapes, mesh, fsdp=fsdp)
    _check_divisible(specs, shapes, mesh)
    o_specs = opt_state_specs(cfg, shapes, mesh, fsdp=fsdp)
    _check_divisible(o_specs, shapes, mesh)


@pytest.mark.parametrize("arch", ALL)
def test_multipod_param_specs_are_valid(arch):
    cfg = ARCHS[arch]
    mesh = _fake_mesh((2, 16, 16), ("pod", "data", "model"))
    shapes = jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(cfg, shapes, mesh, fsdp=True)
    _check_divisible(specs, shapes, mesh)


def test_whisper_odd_vocab_falls_back():
    """51865 doesn't divide 16: the embedding shards d_model instead."""
    cfg = get_arch("whisper-medium")
    mesh = _fake_mesh((16, 16), ("data", "model"))
    shapes = jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(cfg, shapes, mesh)
    assert specs["embed"] == P(None, "model")


def test_llama_vocab_shards():
    cfg = get_arch("llama3-8b")
    mesh = _fake_mesh((16, 16), ("data", "model"))
    shapes = jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(cfg, shapes, mesh)
    assert specs["embed"] == P("model", None)


def test_expert_parallel_vs_tp_within():
    mesh = _fake_mesh((16, 16), ("data", "model"))
    # deepseek: 256 experts % 16 == 0 -> EP on the expert dim
    ds = get_arch("deepseek-v3-671b")
    shapes = jax.eval_shape(lambda: init_model(ds, jax.random.PRNGKey(0)))
    specs = param_specs(ds, shapes, mesh)
    seg1 = specs["stack"]["seg1"][0]["ffn"]["experts"]["up"]
    assert tuple(seg1)[-3] == "model"
    # mixtral: 8 experts % 16 != 0 -> TP within experts (hidden dim)
    mx = get_arch("mixtral-8x22b")
    shapes = jax.eval_shape(lambda: init_model(mx, jax.random.PRNGKey(0)))
    specs = param_specs(mx, shapes, mesh)
    up = specs["stack"]["seg0"][0]["ffn"]["experts"]["up"]
    assert tuple(up)[-1] == "model"


def test_cache_specs_long_context_fallback():
    """B=1 cannot shard over data: the cache length dim takes it instead."""
    cfg = get_arch("jamba-v0.1-52b")
    mesh = _fake_mesh((16, 16), ("data", "model"))
    caches = jax.eval_shape(lambda: init_caches(cfg, 1, 2048, dtype="bfloat16"))
    specs = cache_specs(cfg, caches, mesh, batch_size=1)
    k_spec = None
    for si, slots in specs["stack"].items():
        for slot in slots:
            if "k" in slot.get("mixer", {}):
                k_spec = slot["mixer"]["k"]
    assert k_spec is not None
    assert "data" in tuple(k_spec)  # length dim sharded over data
    _check_divisible(
        specs, jax.eval_shape(lambda: init_caches(cfg, 1, 2048, dtype="bfloat16")), mesh
    )


def test_batch_specs_replicate_tiny_batch():
    cfg = get_arch("llama3-8b")
    mesh = _fake_mesh((16, 16), ("data", "model"))
    assert batch_specs(cfg, mesh, batch_size=256)["tokens"] == P("data", None)
    assert batch_specs(cfg, mesh, batch_size=1)["tokens"] == P(None, None)
