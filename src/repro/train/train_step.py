"""The jitted train step: microbatched grad accumulation, remat policy,
MTP auxiliary loss, optional gradient compression, AdamW -- compiled with
explicit in/out shardings from ``repro.sharding``.

Distributed-optimization posture:
* grad accumulation over ``microbatches`` happens *inside* the jit via
  ``lax.scan``, so the data-parallel gradient all-reduce is emitted once
  per step, not once per microbatch (collective bytes / step drop by M);
* the remat policy is a named knob ('none'|'dots'|'full') -- it is one of
  the software parameters the meshopt codesign sweeps;
* parameter/optimizer shardings are donated, so the step is in-place at
  the XLA level.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..models.model import chunked_ce, forward_hidden, init_model, lm_loss
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..optim.compression import CompressionState, compress_grads, compression_init
from ..sharding.partition import batch_specs, opt_state_specs, param_specs

__all__ = ["TrainConfig", "init_train_state", "make_train_step"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: str = "dots"
    attn_impl: str = "auto"
    mtp_weight: float = 0.3
    compress_grads: bool = False
    fsdp: bool = False  # weight-sharding over the data axes (ZeRO-3 style)
    loss_chunks: int = 0  # 0 = auto: bound live logits to ~256 MB/chip
    opt: AdamWConfig = AdamWConfig()


def _batch_specs_for(cfg: ArchConfig, mesh: Mesh) -> Dict[str, P]:
    """Specs restricted to exactly the keys the data pipeline produces."""
    specs = batch_specs(cfg, mesh)
    keys = ["tokens", "labels"]
    if cfg.frontend or cfg.enc_dec:
        keys.append("frontend")
    return {k: specs.get(k, specs["tokens"]) for k in keys}


def init_train_state(
    cfg: ArchConfig, tcfg: TrainConfig, mesh: Mesh, seed: int = 0
) -> Dict[str, Any]:
    """Initialize params + optimizer state, sharded onto the mesh."""
    abstract = jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(seed)))
    p_specs = param_specs(cfg, abstract, mesh, fsdp=tcfg.fsdp)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)
    params = jax.jit(
        lambda: init_model(cfg, jax.random.PRNGKey(seed)), out_shardings=p_shard
    )()
    o_specs = opt_state_specs(cfg, abstract, mesh, fsdp=tcfg.fsdp)
    mdt = jnp.dtype(tcfg.opt.moment_dtype)
    state = {
        "params": params,
        "opt": {
            "m": jax.jit(
                lambda: jax.tree.map(lambda x: jnp.zeros(x.shape, mdt), abstract),
                out_shardings=jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs),
            )(),
            "v": jax.jit(
                lambda: jax.tree.map(lambda x: jnp.zeros(x.shape, mdt), abstract),
                out_shardings=jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs),
            )(),
            "step": jnp.zeros((), jnp.int32),
        },
    }
    if tcfg.compress_grads:
        state["comp"] = jax.jit(
            lambda: compression_init(abstract).error,
            out_shardings=jax.tree.map(lambda s: NamedSharding(mesh, s), o_specs),
        )()
    return state


def _loss_fn(params, cfg: ArchConfig, tcfg: TrainConfig, batch, n_chunks: int):
    hidden, _, ex = forward_hidden(
        params, cfg, batch, impl=tcfg.attn_impl, remat=tcfg.remat, want_mtp=cfg.mtp
    )
    loss = chunked_ce(cfg, params, hidden, batch["labels"], n_chunks)
    total = loss + ex["aux"]
    metrics = {"lm_loss": loss, "aux_loss": ex["aux"]}
    if "mtp_hidden" in ex:
        # position t predicts token t+2 == labels shifted one further
        mtp = chunked_ce(cfg, params, ex["mtp_hidden"], batch["labels"][:, 1:], n_chunks)
        total = total + tcfg.mtp_weight * mtp
        metrics["mtp_loss"] = mtp
    metrics["loss"] = total
    return total, metrics


def _auto_loss_chunks(cfg: ArchConfig, tcfg: TrainConfig, mesh: Mesh, batch_shape) -> int:
    """Bound live f32 chunk logits to ~256 MB per chip."""
    if tcfg.loss_chunks:
        return tcfg.loss_chunks
    b, s = batch_shape
    chips = mesh.devices.size
    budget = 256e6
    n = int(np.ceil(b // max(1, tcfg.microbatches) * s * cfg.vocab * 4 / (chips * budget)))
    return max(1, min(n, s))


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig, mesh: Mesh):
    """Returns a jitted (state, batch) -> (state, metrics) step."""
    abstract = jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0)))
    p_specs = param_specs(cfg, abstract, mesh, fsdp=tcfg.fsdp)
    o_specs = opt_state_specs(cfg, abstract, mesh, fsdp=tcfg.fsdp)
    b_specs = _batch_specs_for(cfg, mesh)

    def step_fn(state, batch):
        params = state["params"]
        m = tcfg.microbatches
        n_chunks = _auto_loss_chunks(cfg, tcfg, mesh, batch["tokens"].shape)

        if m == 1:
            grads, metrics = jax.grad(
                lambda p: _loss_fn(p, cfg, tcfg, batch, n_chunks), has_aux=True
            )(params)
        else:
            def slice_mb(x):
                b = x.shape[0]
                return x.reshape(m, b // m, *x.shape[1:])

            mbs = jax.tree.map(slice_mb, batch)

            def accum(carry, mb):
                g_acc, _ = carry
                # re-pin the batch sharding: GSPMD loses the data-axis
                # sharding when slicing scan xs, silently replicating the
                # whole microbatch's compute on every data shard (measured
                # 2.7x FLOP inflation at mb=16 -- see EXPERIMENTS.md §Perf)
                mb = {
                    k: jax.lax.with_sharding_constraint(
                        v, NamedSharding(mesh, b_specs[k])
                    )
                    for k, v in mb.items()
                }
                g, mets = jax.grad(
                    lambda p: _loss_fn(p, cfg, tcfg, mb, n_chunks), has_aux=True
                )(params)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32) / m, g_acc, g
                )
                return (g_acc, mets), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            dummy = {
                "lm_loss": jnp.zeros((), jnp.float32),
                "aux_loss": jnp.zeros((), jnp.float32),
                "loss": jnp.zeros((), jnp.float32),
            }
            if cfg.mtp:
                dummy["mtp_loss"] = jnp.zeros((), jnp.float32)
            (grads, metrics), _ = jax.lax.scan(accum, (g0, dummy), mbs)

        new_state = dict(state)
        if tcfg.compress_grads:
            grads, comp = compress_grads(grads, CompressionState(state["comp"]))
            new_state["comp"] = comp.error

        params, opt, opt_metrics = adamw_update(params, grads, state["opt"], tcfg.opt)
        new_state["params"] = params
        new_state["opt"] = opt
        metrics = dict(metrics, **opt_metrics)
        return new_state, metrics

    state_specs = {
        "params": p_specs,
        "opt": {"m": o_specs, "v": o_specs, "step": P()},
    }
    if tcfg.compress_grads:
        state_specs["comp"] = o_specs
    to_sh = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    metric_names = ["lm_loss", "aux_loss", "loss", "grad_norm", "lr"] + (
        ["mtp_loss"] if cfg.mtp else []
    )
    return jax.jit(
        step_fn,
        in_shardings=(to_sh(state_specs), to_sh(b_specs)),
        out_shardings=(
            to_sh(state_specs),
            {k: NamedSharding(mesh, P()) for k in metric_names},
        ),
        donate_argnums=(0,),
    )
