"""TPU codesign bridge: the analytic LM roofline + eq.-18 mesh optimizer."""

import numpy as np
import pytest

from repro.configs.base import SHAPES, get_arch
from repro.core.lmtime import HW, MeshPlan, lm_roofline
from repro.core.meshopt import enumerate_plans, optimize, pareto_plans
from repro.models.model import active_params, count_params


def _cell(arch, shape):
    cfg = get_arch(arch)
    return cfg, SHAPES[shape], count_params(cfg), active_params(cfg)


def test_roofline_terms_positive_and_bounded():
    cfg, shape, n, na = _cell("llama3-8b", "train_4k")
    r = lm_roofline(cfg, shape, MeshPlan(1, 16, 16, 8, "full", False), n, na)
    assert r["compute_s"] > 0 and r["memory_s"] > 0 and r["collective_s"] > 0
    # compute term must be >= ideal 6ND/peak (recompute only adds)
    ideal = 6 * na * shape.tokens / (256 * HW["peak_flops_bf16"])
    assert r["compute_s"] >= ideal * 0.99


def test_fsdp_required_for_huge_models():
    """deepseek at TP-16 without FSDP cannot fit HBM; with FSDP it must."""
    cfg, shape, n, na = _cell("deepseek-v3-671b", "train_4k")
    no = lm_roofline(cfg, shape, MeshPlan(1, 16, 16, 32, "full", False), n, na)
    yes = lm_roofline(cfg, shape, MeshPlan(1, 16, 16, 32, "full", True), n, na)
    assert not no["fits"]
    assert yes["hbm_bytes"] < no["hbm_bytes"]


def test_compression_reduces_collective_term():
    cfg, shape, n, na = _cell("llama3-8b", "train_4k")
    plain = lm_roofline(cfg, shape, MeshPlan(2, 8, 16, 8, "full", False, False), n, na)
    comp = lm_roofline(cfg, shape, MeshPlan(2, 8, 16, 8, "full", False, True), n, na)
    assert comp["collective_s"] < plain["collective_s"]


def test_optimize_returns_feasible_sorted():
    cfg, shape, n, na = _cell("llama3-8b", "train_4k")
    plans = optimize(cfg, shape, n, na, chips=256, top_k=8)
    assert plans, "llama3 train must have feasible plans at 256 chips"
    bounds = [p["bound_s"] for p in plans]
    assert bounds == sorted(bounds)
    for p in plans:
        assert p["fits"]
        mp = p["plan"]
        assert mp["pod"] * mp["data"] * mp["model"] == 256


def test_enumerate_respects_multipod():
    plans = enumerate_plans(512, multi_pod=True, train=False)
    assert all(p.pod == 2 for p in plans)
    assert all(p.chips == 512 for p in plans)


def test_pareto_plans_monotone():
    cfg, shape, n, na = _cell("internlm2-1.8b", "train_4k")
    all_results = []
    for chips in (64, 128, 256):
        all_results += optimize(cfg, shape, n, na, chips=chips, top_k=3)
    front = pareto_plans(all_results)
    bounds = [r["bound_s"] for r in front]
    assert bounds == sorted(bounds, reverse=True)  # more chips -> faster
