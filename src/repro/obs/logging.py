"""Structured JSON line logging for the serving stack.

One logger namespace (``repro``), one formatter: every record renders as
a single canonical-JSON line (sorted keys, compact separators) with
``ts`` (unix seconds), ``level``, ``logger``, ``event``, plus whatever
structured fields the call site attached::

    log = get_logger("repro.gateway")
    log.info("request", route="/v1/query", status=200, dur_us=581)
    # -> {"dur_us":581,"event":"request","level":"info", ...}

Until :func:`configure_logging` runs, the ``repro`` logger holds only a
``NullHandler`` -- imports and tests stay silent by default; the CLI
``serve --log-level`` flag is what turns output on. The active trace id
(:func:`repro.obs.trace.current_trace_id`) is stamped onto every line
emitted inside a traced request, which is how access-log lines join up
with span trees.
"""

from __future__ import annotations

import json
import logging
import sys
from typing import Any, Optional

from .trace import current_trace_id

__all__ = ["configure_logging", "get_logger", "StructuredLogger"]

_ROOT_NAME = "repro"

_LEVELS = {
    "debug": logging.DEBUG,
    "info": logging.INFO,
    "warning": logging.WARNING,
    "error": logging.ERROR,
}


class _JSONFormatter(logging.Formatter):
    def format(self, record: logging.LogRecord) -> str:
        line = {
            "ts": round(record.created, 6),
            "level": record.levelname.lower(),
            "logger": record.name,
            "event": record.getMessage(),
        }
        fields = getattr(record, "repro_fields", None)
        if fields:
            # structured fields never shadow the envelope keys above
            for k, v in fields.items():
                if k not in line:
                    line[k] = _jsonable(v)
        if record.exc_info and record.exc_info[0] is not None:
            line["exc"] = self.formatException(record.exc_info).splitlines()[-1]
        return json.dumps(line, sort_keys=True, separators=(",", ":"),
                          default=str)


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


class StructuredLogger:
    """Thin wrapper binding keyword fields into JSON log lines."""

    __slots__ = ("_log",)

    def __init__(self, log: logging.Logger):
        self._log = log

    def _emit(self, level: int, event: str, fields: dict) -> None:
        if not self._log.isEnabledFor(level):
            return
        tid = current_trace_id()
        if tid is not None and "trace_id" not in fields:
            fields = {**fields, "trace_id": tid}
        self._log.log(level, event, extra={"repro_fields": fields})

    def debug(self, event: str, **fields: Any) -> None:
        self._emit(logging.DEBUG, event, fields)

    def info(self, event: str, **fields: Any) -> None:
        self._emit(logging.INFO, event, fields)

    def warning(self, event: str, **fields: Any) -> None:
        self._emit(logging.WARNING, event, fields)

    def error(self, event: str, **fields: Any) -> None:
        self._emit(logging.ERROR, event, fields)

    def isEnabledFor(self, level: int) -> bool:
        return self._log.isEnabledFor(level)


def get_logger(name: str = _ROOT_NAME) -> StructuredLogger:
    """A structured logger under the ``repro`` namespace (dotted names
    outside it are re-rooted: ``gateway`` -> ``repro.gateway``)."""
    if name != _ROOT_NAME and not name.startswith(_ROOT_NAME + "."):
        name = f"{_ROOT_NAME}.{name}"
    return StructuredLogger(logging.getLogger(name))


# default-quiet: a NullHandler suppresses logging's lastResort fallback so
# unconfigured imports/tests never see stray lines on stderr.
logging.getLogger(_ROOT_NAME).addHandler(logging.NullHandler())


def configure_logging(
    level: str = "info", stream: Optional[Any] = None
) -> None:
    """Install the JSON line handler on the ``repro`` root logger at
    ``level`` (debug|info|warning|error). Idempotent: reconfiguring
    replaces the previous handler rather than stacking a second one."""
    lvl = _LEVELS.get(str(level).lower())
    if lvl is None:
        raise ValueError(
            f"unknown log level {level!r}; expected one of {sorted(_LEVELS)}"
        )
    root = logging.getLogger(_ROOT_NAME)
    for h in list(root.handlers):
        if getattr(h, "_repro_obs_handler", False):
            root.removeHandler(h)
    handler = logging.StreamHandler(stream if stream is not None else sys.stderr)
    handler.setFormatter(_JSONFormatter())
    handler._repro_obs_handler = True  # type: ignore[attr-defined]
    root.addHandler(handler)
    root.setLevel(lvl)
    root.propagate = False
