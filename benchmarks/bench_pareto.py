"""Paper Fig. 3: design-space exploration + Pareto fronts, 2D & 3D classes,
with the stock-GPU comparison points and improvement percentages."""

from __future__ import annotations

import time

import numpy as np

from repro.core import GTX980, MAXWELL, TITAN_X, codesign, enumerate_hw_space
from repro.core.codesign import evaluate_fixed_hw
from repro.core.pareto import pareto_mask
from repro.core.workload import paper_workload

from .common import SMOKE_HW_STRIDE, STENCIL_CLASSES as CLASSES, cache_json, emit, skey, smoke
# paper-reported improvements for the same comparisons (for the derived col)
PAPER = {
    ("2d", "gtx980"): 104.0,
    ("2d", "titanx"): 69.0,
    ("3d", "gtx980"): 123.0,
    ("3d", "titanx"): 126.0,
}


def _solve(cls: str) -> dict:
    wl = paper_workload(CLASSES[cls], name=f"paper-{cls}")
    hw = enumerate_hw_space(MAXWELL, max_area=650.0)
    if smoke():
        hw = hw.downsample(SMOKE_HW_STRIDE)
    t0 = time.perf_counter()
    res = codesign(wl, hw=hw)  # engine="auto": compiled sweep when available
    solve_s = time.perf_counter() - t0
    g = res.gflops()
    mask = pareto_mask(hw.area, g)
    out = {
        "n_designs": int(len(hw)),
        "n_pareto": int(mask.sum()),
        "solve_s": solve_s,
        "pareto_area": hw.area[mask].tolist(),
        "pareto_gflops": g[mask].tolist(),
    }
    for name, point in (("gtx980", GTX980), ("titanx", TITAN_X)):
        _, stock = evaluate_fixed_hw(wl, point)
        a = MAXWELL.area_point(point)
        i, best = res.best(max_area=a)
        out[name] = {
            "stock_gflops": stock,
            "best_gflops": best,
            "area": a,
            "improvement_pct": 100 * (best / stock - 1),
            "best_hw": vars(res.hw.point(i)),
        }
    return out


def run() -> None:
    for cls in CLASSES:
        r = cache_json(skey(f"pareto_{cls}"), lambda cls=cls: _solve(cls))
        us = r["solve_s"] * 1e6
        emit(
            f"pareto_{cls}_designs", us,
            f"{r['n_designs']} feasible; {r['n_pareto']} Pareto "
            f"({100*r['n_pareto']/r['n_designs']:.1f}%; paper: ~1%)",
        )
        for gpu in ("gtx980", "titanx"):
            d = r[gpu]
            emit(
                f"pareto_{cls}_vs_{gpu}", us,
                f"stock {d['stock_gflops']:.0f} -> codesigned {d['best_gflops']:.0f} "
                f"GFLOP/s (+{d['improvement_pct']:.0f}%; paper: +{PAPER[(cls, gpu)]:.0f}%)",
            )
