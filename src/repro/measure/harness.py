"""Timing harness over the tile-parameterized Pallas stencils.

One measurement = one (stencil, problem size, tile config) triple executed
for ``steps`` time steps by :func:`repro.kernels.pallas_stencils
.stencil_run_tiled`, timed with the standard discipline:

* **warmup** calls first (compilation + caches), never timed;
* ``repeats`` timed calls, each fenced by ``block_until_ready`` (wall time
  without device sync measures dispatch, not execution);
* the **median** is recorded (robust against scheduler noise, the usual
  choice for microbenchmarks).

Records carry everything the calibration fit needs to reproduce the model
prediction for the same configuration: the size row, the tile row (in
``sweep.SW_NAMES`` order), and the nominal hardware point the measured
machine is described as. Runs serialize to plain JSON
(:meth:`MeasurementRun.to_payload`) so they can live in the artifact store
as ``kind: "measurement"`` manifests.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.timemodel import (
    MAXWELL_GPU,
    STENCILS,
    GPUSpec,
    ProblemSize,
    feasible,
)
from repro.kernels.pallas_stencils import TILE_NAMES, normalize_tiles, run_tiled
from repro.obs.metrics import get_registry as _obs_registry

# ---- observability (repro.obs; no-ops under REPRO_OBS_DISABLED=1) --------
_REG = _obs_registry()
_M_POINTS = _REG.counter(
    "repro_measure_points_total",
    "measured (stencil, size, tiles) points, by stencil",
    labels=("stencil",),
)
_M_POINT_SECONDS = _REG.histogram(
    "repro_measure_point_seconds",
    "median wall seconds of one measured point (the recorded time_s)",
)

__all__ = [
    "MeasurementRecord",
    "MeasurementRun",
    "STOCK_HW",
    "STOCK_HW_BY_GPU",
    "stock_hw",
    "default_grid",
    "frame_tiles",
    "feasible_tiles",
    "measure_one",
    "measure_grid",
]

#: nominal description of the measured machine as a paper hardware point
#: (n_SM, n_V, M_SM kB). The calibration fit holds this fixed and moves
#: only the machine parameters (C_iter, bandwidth, launch overhead); the
#: stock points keep the numbers comparable with the paper's §IV.B /
#: Table I (GTX-980: 16 SMs, Titan X: 24 SMs, both 128 lanes / 96 kB).
STOCK_HW: Dict[str, float] = {"n_sm": 16.0, "n_v": 128.0, "m_sm": 96.0}
STOCK_HW_BY_GPU: Dict[str, Dict[str, float]] = {
    "gtx980": STOCK_HW,
    "titanx": {"n_sm": 24.0, "n_v": 128.0, "m_sm": 96.0},
}


def stock_hw(gpu: GPUSpec) -> Dict[str, float]:
    """The nominal hardware point a measurement on ``gpu``'s family is
    described as -- a titanx-framed run must be predicted at the Titan X's
    SM count, not the GTX-980's."""
    return dict(STOCK_HW_BY_GPU.get(gpu.name, STOCK_HW))


@dataclasses.dataclass(frozen=True)
class MeasurementRecord:
    """One timed (stencil, size, tiles) point plus its context."""

    stencil: str
    size: Tuple[int, int, int, int]  # (s1, s2, s3, t) -- t = measured steps
    tiles: Tuple[int, ...]  # TILE_NAMES order
    time_s: float  # median wall seconds for the whole t-step run
    hw: Tuple[float, float, float]  # (n_sm, n_v, m_sm) nominal description
    repeats: int = 1
    warmup: int = 1
    #: every timed repeat, in call order (time_s is their median). Optional
    #: telemetry: serialized only when present, tolerated absent so old
    #: manifests (and hand-written fixtures) still load.
    times_s: Optional[Tuple[float, ...]] = None

    def problem_size(self) -> ProblemSize:
        s1, s2, s3, t = self.size
        return ProblemSize(s1=s1, s2=s2, t=t, s3=s3)

    def tile_dict(self) -> Dict[str, int]:
        return dict(zip(TILE_NAMES, self.tiles))

    def to_json(self) -> dict:
        out = {
            "stencil": self.stencil,
            "size": list(self.size),
            "tiles": list(self.tiles),
            "time_s": float(self.time_s),
            "hw": list(self.hw),
            "repeats": int(self.repeats),
            "warmup": int(self.warmup),
        }
        if self.times_s is not None:
            out["times_s"] = [float(t) for t in self.times_s]
        return out

    @classmethod
    def from_json(cls, obj: Mapping) -> "MeasurementRecord":
        raw_times = obj.get("times_s")
        return cls(
            stencil=str(obj["stencil"]),
            size=tuple(int(v) for v in obj["size"]),
            tiles=tuple(int(v) for v in obj["tiles"]),
            time_s=float(obj["time_s"]),
            hw=tuple(float(v) for v in obj["hw"]),
            repeats=int(obj.get("repeats", 1)),
            warmup=int(obj.get("warmup", 1)),
            times_s=None if raw_times is None
            else tuple(float(t) for t in raw_times),
        )


@dataclasses.dataclass
class MeasurementRun:
    """A list of records plus run-level context (the persistable unit)."""

    records: List[MeasurementRecord]
    gpu_name: str  # GPU family whose constants frame the fit
    backend: str  # jax backend that executed the kernels
    interpret: bool  # True = Pallas interpret mode (CPU CI lane)
    note: str = ""

    def to_payload(self) -> dict:
        """Plain-JSON payload (the artifact-store manifest body)."""
        return {
            "records": [r.to_json() for r in self.records],
            "gpu_name": self.gpu_name,
            "backend": self.backend,
            "interpret": bool(self.interpret),
            "note": self.note,
        }

    @classmethod
    def from_payload(cls, obj: Mapping) -> "MeasurementRun":
        return cls(
            records=[MeasurementRecord.from_json(r) for r in obj["records"]],
            gpu_name=str(obj["gpu_name"]),
            backend=str(obj["backend"]),
            interpret=bool(obj["interpret"]),
            note=str(obj.get("note", "")),
        )

    def stencil_names(self) -> List[str]:
        seen: Dict[str, None] = {}
        for r in self.records:
            seen.setdefault(r.stencil)
        return list(seen)


def frame_tiles(name: str, tiles) -> Tuple[int, ...]:
    """Normalized tile tuple in the frame the MODEL evaluates it: 2D
    stencils get ``t_s3`` pinned to 1. The 2D kernel never reads ``t_s3``
    but the time model's compute term multiplies by it for every
    dimensionality, so a 2D record stamped ``t_s3=8`` would make the fit
    absorb an 8x compute factor the kernel never executed -- and the
    eq.-18 sweep's ``LATTICE_2D`` evaluates 2D tiles at ``t_s3=1``, the
    frame calibrated parameters must transfer to."""
    t = list(normalize_tiles(tiles))
    if STENCILS[name].dims == 2:
        t[TILE_NAMES.index("t_s3")] = 1
    return tuple(t)


def feasible_tiles(
    name: str,
    tile_candidates: Iterable[Mapping[str, int]],
    gpu: GPUSpec = MAXWELL_GPU,
    hw: Mapping[str, float] = None,
) -> List[Dict[str, int]]:
    """Keep only candidates the analytical model itself deems feasible at
    the nominal hardware point (eqs. 9-15). An infeasible tile predicts
    ``+inf``, which no fit can use -- filtering here keeps the measurement
    grid and the model's domain aligned. Candidates are put in the
    :func:`frame_tiles` frame first, and deduped (distinct ``t_s3``
    values collapse for 2D stencils)."""
    hw = dict(STOCK_HW if hw is None else hw)
    st = STENCILS[name]
    out: List[Dict[str, int]] = []
    seen = set()
    for cand in tile_candidates:
        framed = frame_tiles(name, cand)
        if framed in seen:
            continue
        seen.add(framed)
        t = dict(zip(TILE_NAMES, framed))
        ok = feasible(
            st, gpu, hw["n_sm"], hw["n_v"], hw["m_sm"],
            t["t_s1"], t["t_s2"], t["t_t"], t["k"], t["t_s3"],
        )
        if bool(np.asarray(ok)):
            out.append(t)
    return out


def measure_one(
    name: str,
    shape: Sequence[int],
    steps: int,
    tiles: Mapping[str, int],
    warmup: int = 1,
    repeats: int = 3,
    interpret: Optional[bool] = None,
    hw: Mapping[str, float] = None,
    seed: int = 0,
) -> MeasurementRecord:
    """Time one configuration (median of ``repeats`` fenced runs)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    hw = dict(STOCK_HW if hw is None else hw)
    tile_tuple = frame_tiles(name, tiles)  # 2D: t_s3 pinned to 1
    x = jax.random.normal(jax.random.PRNGKey(seed), tuple(shape), jnp.float32)
    x = jax.block_until_ready(x)

    def run() -> float:
        t0 = time.perf_counter()
        jax.block_until_ready(
            run_tiled(name, x, steps=steps, tiles=tiles, interpret=interpret)
        )
        return time.perf_counter() - t0

    for _ in range(max(0, warmup)):
        run()
    times = [run() for _ in range(max(1, repeats))]
    dims = STENCILS[name].dims
    size = (
        int(shape[0]),
        int(shape[1]),
        int(shape[2]) if dims == 3 else 1,
        int(steps),
    )
    median = float(statistics.median(times))
    _M_POINTS.labels(stencil=name).inc()
    _M_POINT_SECONDS.observe(median)
    return MeasurementRecord(
        stencil=name,
        size=size,
        tiles=tile_tuple,
        time_s=median,
        hw=(hw["n_sm"], hw["n_v"], hw["m_sm"]),
        repeats=int(repeats),
        warmup=int(warmup),
        times_s=tuple(float(t) for t in times),
    )


def default_grid(
    smoke: bool = True, gpu: GPUSpec = MAXWELL_GPU
) -> Dict[str, List[dict]]:
    """stencil -> list of {"shape", "steps", "tiles"} configs.

    The smoke grid is sized for the CI interpret-mode lane (seconds, not
    minutes) while still varying every axis the fit needs signal on: tile
    shape (footprint / bandwidth term), time-tile depth (launch-overhead
    term via the pass count), and problem size (compute term). Tile
    candidates are feasibility-filtered against ``gpu``'s family at its
    :func:`stock_hw` point, so the grid and the fit share one frame.
    """
    if smoke:
        shapes_2d = [(48, 64), (96, 128)]
        shapes_3d = [(16, 16, 32)]
        steps = 4
        tile_cands = [
            {"t_s1": 8, "t_s2": 32, "t_t": 2, "k": 1},
            {"t_s1": 16, "t_s2": 64, "t_t": 2, "k": 2},
            {"t_s1": 32, "t_s2": 64, "t_t": 4, "k": 1},
            {"t_s1": 8, "t_s2": 32, "t_t": 2, "k": 1, "t_s3": 4},
            {"t_s1": 4, "t_s2": 32, "t_t": 4, "k": 1, "t_s3": 4},
        ]
    else:
        shapes_2d = [(256, 256), (512, 512), (1024, 1024)]
        shapes_3d = [(48, 48, 64), (96, 96, 96)]
        steps = 8
        tile_cands = [
            {"t_s1": 8, "t_s2": 32, "t_t": 2, "k": 1},
            {"t_s1": 16, "t_s2": 64, "t_t": 2, "k": 2},
            {"t_s1": 32, "t_s2": 128, "t_t": 4, "k": 4},
            {"t_s1": 64, "t_s2": 256, "t_t": 8, "k": 2},
        ]
    grid: Dict[str, List[dict]] = {}
    for name, st in STENCILS.items():
        shapes = shapes_3d if st.dims == 3 else shapes_2d
        cands = feasible_tiles(name, tile_cands, gpu, stock_hw(gpu))
        grid[name] = [
            {"shape": shape, "steps": steps, "tiles": t}
            for shape in shapes
            for t in cands
        ]
    return grid


def measure_grid(
    grid: Optional[Dict[str, List[dict]]] = None,
    warmup: int = 1,
    repeats: int = 3,
    interpret: Optional[bool] = None,
    gpu: GPUSpec = MAXWELL_GPU,
    note: str = "",
) -> MeasurementRun:
    """Run every configuration of a :func:`default_grid`-shaped grid.
    Records are stamped with ``gpu``'s family stock hardware point (a
    config may override with its own ``"hw"``)."""
    if grid is None:
        grid = default_grid(gpu=gpu)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    records: List[MeasurementRecord] = []
    for name, configs in grid.items():
        for cfg in configs:
            records.append(
                measure_one(
                    name,
                    cfg["shape"],
                    cfg["steps"],
                    cfg["tiles"],
                    warmup=warmup,
                    repeats=repeats,
                    interpret=interpret,
                    hw=cfg.get("hw", stock_hw(gpu)),
                )
            )
    return MeasurementRun(
        records=records,
        gpu_name=gpu.name,
        backend=jax.default_backend(),
        interpret=bool(interpret),
        note=note,
    )
