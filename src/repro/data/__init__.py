"""Data substrate: deterministic synthetic token pipeline, host-sharded."""

from .pipeline import DataConfig, SyntheticPipeline, make_batch  # noqa: F401
