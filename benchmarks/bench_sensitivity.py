"""Paper Table II: workload sensitivity -- per-stencil optimal architecture
in the 425-450 mm^2 band, computed 'for free' from cached cell times
(§V.B re-weighting)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import MAXWELL, codesign, enumerate_hw_space
from repro.core.workload import paper_workload

from .common import SMOKE_HW_STRIDE, STENCIL_CLASSES, cache_json, emit, skey, smoke

#: paper Table II rows (n_SM, n_V, M_SM, area, GFLOP/s) for the derived col
PAPER_TABLE = {
    "jacobi2d": (32, 128, 24, 438, 2059),
    "heat2d": (22, 256, 12, 447, 3017),
    "gradient2d": (28, 160, 24, 431, 4963),
    "laplacian2d": (28, 160, 12, 426, 2549),
    "heat3d": (18, 288, 192, 447, 3600),
    "laplacian3d": (8, 896, 96, 446, 1427),
}


def _solve() -> dict:
    out = {}
    hw = enumerate_hw_space(MAXWELL, max_area=650.0)
    if smoke():
        hw = hw.downsample(SMOKE_HW_STRIDE)
    for cls in STENCIL_CLASSES.values():
        wl = paper_workload(cls)
        t0 = time.perf_counter()
        res = codesign(wl, hw=hw)  # engine="auto": compiled sweep
        solve_s = time.perf_counter() - t0
        cells = list(wl.cells)
        for name in cls:
            freqs = np.array(
                [1.0 / 16 if c.stencil.name == name else 0.0 for c in cells]
            )
            g = res.gflops(freqs)
            g = np.where((hw.area >= 425) & (hw.area <= 450), g, -np.inf)
            i = int(np.argmax(g))
            p = res.hw.point(i)
            out[name] = {
                "n_sm": p.n_sm, "n_v": p.n_v, "m_sm": p.m_sm,
                "area": float(hw.area[i]), "gflops": float(g[i]),
                "solve_s": solve_s,
            }
    return out


def run() -> None:
    table = cache_json(skey("sensitivity"), _solve)
    for name, r in table.items():
        ps = PAPER_TABLE[name]
        emit(
            f"sensitivity_{name}", r["solve_s"] * 1e6,
            f"n_SM={r['n_sm']} n_V={r['n_v']} M_SM={r['m_sm']:.0f} "
            f"area={r['area']:.0f} {r['gflops']:.0f} GFLOP/s "
            f"(paper: n_SM={ps[0]} n_V={ps[1]} M_SM={ps[2]} {ps[4]} GFLOP/s)",
        )
