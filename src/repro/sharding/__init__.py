"""Sharding rules: parameter/activation/cache PartitionSpecs (DP/TP/EP/SP)."""

from .partition import (  # noqa: F401
    batch_specs,
    cache_specs,
    data_axes,
    opt_state_specs,
    param_specs,
)
