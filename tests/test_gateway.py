"""repro.service gateway: wire codec round trips, multi-artifact routing,
HTTP transport byte-identity vs the in-process server (the acceptance
property), structured error paths, pool LRU bounds, concurrent clients
across two artifacts, and the CLI's clean failure on missing/empty
stores."""

import json
import math
import subprocess
import sys
import tempfile
import threading
import urllib.request

import numpy as np
import pytest

from repro.core import MAXWELL, enumerate_hw_space
from repro.core.timemodel import MAXWELL_GPU, TITANX_GPU
from repro.core.workload import paper_workload
from repro.service import (
    AmbiguousRouteError,
    ArtifactStore,
    CodesignServer,
    Gateway,
    GatewayClient,
    QueryRequest,
    RemoteError,
    UnknownArtifactError,
    WireError,
    serve_http,
    wire,
)

#: tiny space (~81 points) + two-stencil workload keep the numpy sweeps in
#: test time; two GPUs give genuinely different matrices to route between.
STRIDE = 64
STENCILS = ["heat2d", "jacobi2d"]


def small_hw():
    return enumerate_hw_space(MAXWELL, max_area=650.0).downsample(STRIDE)


@pytest.fixture(scope="module")
def fleet():
    """One store holding two artifacts (gtx980 + titanx), their oracle
    servers, a gateway, and a live HTTP server -- built once."""
    root = tempfile.mkdtemp(prefix="gwstore-")
    store = ArtifactStore(root)
    wl = paper_workload(STENCILS)
    hw = small_hw()
    oracles = {}
    for gpu in (MAXWELL_GPU, TITANX_GPU):
        srv = CodesignServer(
            store, workload=wl, gpu=gpu, hw=hw, engine="numpy", batch_window=0.0
        )
        srv.ensure_artifact()
        oracles[gpu.name] = srv
    gw = Gateway(root, pool_size=2, batch_window=0.0)
    httpd = serve_http(gw)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    url = "http://%s:%d" % httpd.server_address[:2]
    yield store, oracles, gw, url
    httpd.shutdown()
    httpd.server_close()


def _req(**kw):
    kw.setdefault("freqs", {"heat2d": 1.0})
    kw.setdefault("use_cache", False)  # keep `cached` deterministic across
    return QueryRequest(**kw)         # oracle and gateway LRUs


# ---------------------------------------------------------------------------
# wire codec
# ---------------------------------------------------------------------------
def test_wire_request_round_trip_all_fields():
    req = QueryRequest(
        freqs={"heat2d": 2.0, "jacobi2d": 0.5},
        max_area=math.inf,
        min_area=120.0,
        top_k=7,
        pareto=True,
        fix={"n_sm": 16.0, "m_sm": 96.0},
        use_cache=False,
    )
    data = wire.encode_request(req, artifact="abc123", route={"gpu": "titanx"})
    got, artifact, route = wire.decode_request(data)
    assert got == req
    assert artifact == "abc123"
    assert route == {"gpu": "titanx"}
    # canonical encoding: same object -> same bytes, always
    assert wire.encode_request(req, artifact="abc123", route={"gpu": "titanx"}) == data
    # cell_freqs variant (sequences survive)
    req2 = QueryRequest(cell_freqs=[1.0] * 4, max_area=450.0)
    got2, _, _ = wire.decode_request(wire.encode_request(req2))
    assert list(got2.cell_freqs) == [1.0] * 4


def test_wire_nonfinite_floats_round_trip_exactly():
    req, _, _ = wire.decode_request(wire.encode_request(QueryRequest()))
    assert req.max_area == math.inf
    # a nan travels as a tag and comes back as a real nan
    obj = wire._unjsonify(wire._jsonify({"x": math.nan, "y": -math.inf}))
    assert math.isnan(obj["x"]) and obj["y"] == -math.inf


def test_wire_coerces_scalars_and_rejects_garbage():
    """JSON-ly typed scalars ('450', 3.0 for top_k) coerce at decode time;
    uncoercible garbage fails as bad_request, never a deep engine error."""
    got, _, _ = wire.decode_request(
        b'{"v": 1, "request": {"max_area": "450", "top_k": 3.0}}'
    )
    assert got.max_area == 450.0 and isinstance(got.max_area, float)
    assert got.top_k == 3 and isinstance(got.top_k, int)
    with pytest.raises(WireError, match="bad request field"):
        wire.decode_request(b'{"v": 1, "request": {"max_area": "plenty"}}')
    with pytest.raises(WireError, match="must be a boolean"):
        wire.decode_request(b'{"v": 1, "request": {"pareto": "yes"}}')


def test_wire_rejects_malformed_and_unknown():
    with pytest.raises(WireError, match="malformed JSON"):
        wire.decode_request(b"{not json")
    with pytest.raises(WireError, match="must be a JSON object"):
        wire.decode_request(b"[1,2]")
    with pytest.raises(WireError) as ei:
        wire.decode_request(b'{"v": 99, "request": {}}')
    assert ei.value.code == "unsupported_version"
    with pytest.raises(WireError, match="unknown request fields"):
        wire.decode_request(b'{"v": 1, "request": {"max_aera": 5}}')
    with pytest.raises(WireError, match="unknown envelope fields"):
        wire.decode_request(b'{"v": 1, "request": {}, "extra": 1}')
    with pytest.raises(WireError, match="'artifact' must be a string"):
        wire.decode_request(b'{"v": 1, "request": {}, "artifact": 7}')
    with pytest.raises(WireError, match="'freqs' must be an object"):
        wire.decode_request(b'{"v": 1, "request": {"freqs": [1, 2]}}')


def test_wire_response_round_trip_bit_identical(fleet):
    _, oracles, _, _ = fleet
    # exercise every optional field: pareto, what-if baseline, and the
    # infeasible -inf/empty shape
    for req in (
        _req(top_k=5, pareto=True, fix={"n_sm": 16.0}),
        _req(max_area=1.0),  # infeasible: best_index=-1, -inf gflops
    ):
        resp = oracles["gtx980"].query(req)
        data = wire.encode_response(resp)
        back = wire.decode_response(data)
        assert wire.encode_response(back) == data  # decode inverts encode
        assert back.best_index == resp.best_index
        assert back.best_gflops == resp.best_gflops  # incl. -inf exactly
        assert back.top_k == resp.top_k
        if resp.pareto_indices is not None:
            np.testing.assert_array_equal(back.pareto_indices, resp.pareto_indices)
    # a structured error decodes as RemoteError carrying the code
    with pytest.raises(RemoteError) as ei:
        wire.decode_response(wire.encode_error("unknown_artifact", "nope"), 404)
    assert ei.value.code == "unknown_artifact" and ei.value.http_status == 404


# ---------------------------------------------------------------------------
# gateway: discovery, routing, pool
# ---------------------------------------------------------------------------
def test_gateway_indexes_both_artifacts_with_routing_attrs(fleet):
    store, oracles, gw, _ = fleet
    keys = {srv.key for srv in oracles.values()}
    assert set(gw.keys()) >= keys
    by_key = {row["key"]: row for row in gw.entries()}
    for name, srv in oracles.items():
        row = by_key[srv.key]
        assert row["gpu"] == name
        assert row["stencils"] == sorted(STENCILS)
        assert row["engine"] == "numpy"
        assert row["hw"] == len(small_hw())


def test_gateway_routes_by_key_and_selector(fleet):
    _, oracles, gw, _ = fleet
    req = _req(max_area=500.0, top_k=3)
    for name, srv in oracles.items():
        want = srv.query(req)
        by_key = gw.query(req, artifact=srv.key)
        by_gpu = gw.query(req, route={"gpu": name})
        for got in (by_key, by_gpu):
            assert got.artifact_key == srv.key
            assert got.best_index == want.best_index
            assert got.best_gflops == want.best_gflops
    # the two GPUs genuinely answer differently (different bandwidth)
    a = gw.query(req, route={"gpu": "gtx980"})
    b = gw.query(req, route={"gpu": "titanx"})
    assert a.best_gflops != b.best_gflops


def test_gateway_routing_errors(fleet):
    _, _, gw, _ = fleet
    req = _req()
    with pytest.raises(UnknownArtifactError, match="no stored artifact"):
        gw.query(req, artifact="0" * 20)
    with pytest.raises(UnknownArtifactError):
        gw.query(req, route={"gpu": "voodoo2"})
    with pytest.raises(AmbiguousRouteError, match="pin one"):
        gw.query(req, route={"stencils": ["heat2d"]})  # both artifacts serve it
    with pytest.raises(AmbiguousRouteError, match="name one"):
        gw.query(req)  # two artifacts, no selector
    with pytest.raises(ValueError, match="unknown route selector"):
        gw.query(req, route={"gpus": "gtx980"})


def test_gateway_pool_is_lru_bounded(fleet):
    store, oracles, _, _ = fleet
    gw = Gateway(store.root, pool_size=1, batch_window=0.0)
    req = _req(max_area=500.0)
    keys = [srv.key for srv in oracles.values()]
    for key in keys + keys:  # A, B, A, B: every switch evicts
        resp = gw.query(req, artifact=key)
        assert resp.artifact_key == key
    assert gw.stats["pool_evictions"] >= 3
    assert gw.stats["pool_instantiations"] >= 4
    assert len(gw._pool) == 1
    # answers stay correct after re-instantiation
    for name, srv in oracles.items():
        assert gw.query(req, artifact=srv.key).best_index == srv.query(req).best_index


def test_gateway_discovers_new_artifact_on_demand():
    # own store root: adding an artifact to the shared fleet store would
    # make the other tests' {"gpu": "gtx980"} selector ambiguous
    store = ArtifactStore(tempfile.mkdtemp(prefix="gwlate-"))
    gw = Gateway(store.root, batch_window=0.0)
    n0 = len(gw)
    wl3 = paper_workload(["heat3d"], name="late-arrival")
    srv3 = CodesignServer(
        store, workload=wl3, hw=small_hw(), engine="numpy", batch_window=0.0
    )
    srv3.ensure_artifact()  # lands AFTER the gateway indexed the store
    want = srv3.query(_req(freqs={"heat3d": 1.0}))
    got = gw.query(_req(freqs={"heat3d": 1.0}), artifact=srv3.key)  # on-demand rescan
    assert got.best_index == want.best_index
    assert len(gw) == n0 + 1
    assert gw.stats["rescans"] >= 2
    # selector routing sees it too
    assert gw.resolve(route={"workload": "late-arrival"}) == srv3.key


def test_from_artifact_honors_spec_lattices_for_unused_dims():
    """The content key digests BOTH lattice tables; a custom lattice for a
    dimensionality the workload never exercises must still reproduce the
    key from the stored spec (the per-cell tables alone cannot)."""
    from repro.core.solver import TileLattice

    store = ArtifactStore(tempfile.mkdtemp(prefix="gwlat-"))
    custom_3d = TileLattice(
        t_s1=(1, 2), t_s2=(32, 64), t_t=(2, 4), k=(1, 2), t_s3=(1, 2)
    )
    srv = CodesignServer(
        store, workload=paper_workload(["heat2d"]), hw=small_hw(),
        engine="numpy", lattice_3d=custom_3d, batch_window=0.0,
    )
    srv.ensure_artifact()
    art = store.get(srv.key)
    warm = CodesignServer.from_artifact(store, art, batch_window=0.0)
    assert warm.key == srv.key
    assert warm.query(_req()).best_index == srv.query(_req()).best_index


def test_from_artifact_reproduces_key_and_answers(fleet):
    store, oracles, _, _ = fleet
    for srv in oracles.values():
        art = store.get(srv.key)
        warm = CodesignServer.from_artifact(store, art, batch_window=0.0)
        assert warm.key == art.key
        assert warm.warm
        req = _req(top_k=4, pareto=True)
        a, b = warm.query(req), srv.query(req)
        assert wire.encode_response(a) == wire.encode_response(b)
    assert warm.stats["artifact_builds"] == 0


# ---------------------------------------------------------------------------
# HTTP transport: the acceptance property + error paths
# ---------------------------------------------------------------------------
def test_http_query_is_byte_identical_to_in_process(fleet):
    _, oracles, _, url = fleet
    client = GatewayClient(url)
    rng = np.random.default_rng(5)
    for name, srv in oracles.items():
        for _ in range(3):
            w = rng.uniform(0.1, 1.0, size=2)
            req = _req(
                freqs=dict(zip(STENCILS, w)),
                max_area=float(rng.uniform(350, 650)),
                top_k=3,
                pareto=True,
            )
            raw = client.query_bytes(req, route={"gpu": name})
            assert raw == wire.encode_response(srv.query(req))
    # and the infeasible case crosses the wire exactly (-inf survives)
    raw = client.query_bytes(_req(max_area=1.0), route={"gpu": "gtx980"})
    assert raw == wire.encode_response(oracles["gtx980"].query(_req(max_area=1.0)))
    resp = wire.decode_response(raw)
    assert resp.best_index == -1 and resp.best_gflops == -math.inf


def test_http_error_paths_are_structured(fleet):
    _, _, _, url = fleet
    client = GatewayClient(url)

    def status_and_code(body: bytes, status: int):
        with pytest.raises(RemoteError) as ei:
            wire.decode_response(body, status)
        return ei.value

    # malformed JSON -> 400 bad_request (never a traceback)
    req = urllib.request.Request(
        url + "/v1/query", data=b"{oops", method="POST",
        headers={"Content-Type": "application/json"},
    )
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req)
    assert ei.value.code == 400
    err = status_and_code(ei.value.read(), 400)
    assert err.code == "bad_request" and "JSON" in err.message

    # unknown artifact -> 404 unknown_artifact
    with pytest.raises(RemoteError) as ei:
        client.query(_req(), artifact="f" * 20)
    assert ei.value.code == "unknown_artifact" and ei.value.http_status == 404

    # ambiguous route -> 409
    with pytest.raises(RemoteError) as ei:
        client.query(_req())
    assert ei.value.code == "ambiguous_route" and ei.value.http_status == 409

    # semantic rejection from the engine -> 400 bad_request
    with pytest.raises(RemoteError) as ei:
        client.query(_req(freqs={"nosuch": 1.0}), route={"gpu": "gtx980"})
    assert ei.value.code == "bad_request" and "nosuch" in ei.value.message

    # unknown endpoint -> 404 not_found
    with pytest.raises(RemoteError) as ei:
        wire.decode_response(client._http("/v2/query", b"{}"), client._last_status)
    assert ei.value.code == "not_found"

    # wrong wire version -> 400 unsupported_version
    with pytest.raises(RemoteError) as ei:
        wire.decode_response(
            client._http("/v1/query", b'{"v": 9, "request": {}}'),
            client._last_status,
        )
    assert ei.value.code == "unsupported_version"


def test_http_introspection_endpoints(fleet):
    _, oracles, _, url = fleet
    client = GatewayClient(url)
    health = client.health()
    assert health["ok"] and health["artifacts"] >= 2
    rows = {r["key"]: r for r in client.artifacts()}
    for name, srv in oracles.items():
        assert rows[srv.key]["gpu"] == name
    assert client.refresh() >= 2


def test_http_concurrent_clients_route_to_distinct_artifacts(fleet):
    """Eight threads interleave queries against both GPUs through ONE
    gateway; every answer must match that artifact's oracle (no
    cross-artifact bleed) even while requests microbatch."""
    _, oracles, _, url = fleet
    names = list(oracles)
    rng = np.random.default_rng(23)
    reqs = [
        _req(
            freqs=dict(zip(STENCILS, rng.uniform(0.1, 1.0, size=2))),
            max_area=float(rng.uniform(350, 650)),
            top_k=2,
        )
        for _ in range(8)
    ]
    want = [wire.encode_response(oracles[names[i % 2]].query(r))
            for i, r in enumerate(reqs)]
    got = [None] * len(reqs)
    barrier = threading.Barrier(len(reqs))

    def worker(i):
        client = GatewayClient(url)
        barrier.wait()
        got[i] = client.query_bytes(reqs[i], route={"gpu": names[i % 2]})

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(len(reqs))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i, (g, w) in enumerate(zip(got, want)):
        assert g == w, f"request {i} diverged from its artifact's oracle"


# ---------------------------------------------------------------------------
# CLI: clean failures (no tracebacks) on missing/empty stores
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("case", ["missing", "empty"])
def test_cli_serve_exits_cleanly_without_artifacts(case, tmp_path, subprocess_env):
    root = tmp_path / "nosuch-store"
    if case == "empty":
        root.mkdir()
    proc = subprocess.run(
        [sys.executable, "-m", "repro.service.cli", "serve",
         "--store", str(root), "--port", "0"],
        capture_output=True, text=True, timeout=60, env=subprocess_env,
    )
    assert proc.returncode == 2
    assert proc.stderr.startswith("error:")
    assert "Traceback" not in proc.stderr
    assert str(root) in proc.stderr


def test_cli_serve_root_only_skips_default_store(fleet, subprocess_env):
    """`serve --root <store>` must not require the default cache dir to
    exist (it is only consulted when no root is named explicitly)."""
    store, _, _, _ = fleet
    env = dict(subprocess_env)
    env["HOME"] = tempfile.mkdtemp(prefix="gwhome-")  # no default store here
    env.pop("REPRO_STORE", None)
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.service.cli", "serve",
         "--root", store.root, "--port", "0"],
        stdout=subprocess.PIPE, text=True, env=env,
    )
    try:
        served = False
        for line in proc.stdout:
            if "serving on http://" in line:
                served = True
                break
        assert served, "serve --root <valid store> failed to start"
    finally:
        proc.terminate()
        proc.wait(timeout=30)


def test_cli_query_url_unreachable_exits_cleanly(subprocess_env):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.service.cli", "query",
         "--url", "http://127.0.0.1:9", "--stencil", "heat2d"],
        capture_output=True, text=True, timeout=60, env=subprocess_env,
    )
    assert proc.returncode == 2
    assert "cannot reach gateway" in proc.stderr
    assert "Traceback" not in proc.stderr


def test_store_refuses_missing_root_when_not_creating(tmp_path):
    with pytest.raises(FileNotFoundError, match="does not exist"):
        ArtifactStore(str(tmp_path / "nope"), create=False)
    with pytest.raises(FileNotFoundError):
        Gateway(str(tmp_path / "nope"))


def test_artifact_routing_row_falls_back_without_block(fleet):
    """Artifacts written before the manifest grew a 'routing' block still
    produce a full routing row (derived from workload/gpu/spec)."""
    store, oracles, _, _ = fleet
    srv = oracles["titanx"]
    art = store.get(srv.key)
    m = json.loads(json.dumps(art.manifest))  # deep copy
    m.pop("routing", None)
    art.manifest = m
    row = art.routing()
    assert row["gpu"] == "titanx"
    assert row["stencils"] == sorted(STENCILS)
    assert row["key"] == srv.key
