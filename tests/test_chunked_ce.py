"""chunked_ce must equal plain full-logits CE (fwd and grad) -- it is a
memory optimization, not an approximation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import forward, forward_hidden, init_model
from repro.models.model import chunked_ce, lm_loss, _head

# multi-second jit compiles: the fast CI lane deselects these (-m "not slow");
# the weekly scheduled lane (and a bare local `pytest`) still runs them
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("n_chunks", [1, 2, 4, 7, 8])
def test_chunked_matches_plain(n_chunks):
    cfg = get_arch("internlm2-1.8b").reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab),
    }
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), -1, cfg.vocab)
    hidden, _, _ = forward_hidden(params, cfg, batch)
    plain = lm_loss(_head(cfg, params, hidden), labels)
    chunked = chunked_ce(cfg, params, hidden, labels, n_chunks)
    np.testing.assert_allclose(float(chunked), float(plain), rtol=1e-6)


def test_chunked_grads_match_plain():
    cfg = get_arch("internlm2-1.8b").reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    }
    labels = jnp.roll(batch["tokens"], -1, axis=1)

    def loss(p, n):
        h, _, _ = forward_hidden(p, cfg, batch)
        return chunked_ce(cfg, p, h, labels, n)

    g1 = jax.grad(lambda p: loss(p, 1))(params)
    g4 = jax.grad(lambda p: loss(p, 4))(params)
    for a, b_ in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-4, atol=1e-6)


def test_all_labels_masked_is_zero():
    cfg = get_arch("internlm2-1.8b").reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    hidden, _, _ = forward_hidden(params, cfg, batch)
    labels = jnp.full((1, 8), -1, jnp.int32)
    assert float(chunked_ce(cfg, params, hidden, labels, 2)) == 0.0
